//! Integration: the full L3 serve path — submit -> queue -> dynamic
//! batcher -> executor (PJRT) -> response — against real artifacts.
//! Skips when `make artifacts` hasn't run.
//!
//! The multi-tenant pool tests at the bottom run unconditionally: they
//! drive the fleet admission path and the graph executor against capped
//! `DevicePool`s directly (pure simulation, no artifacts needed).

use std::sync::mpsc::Receiver;
use std::time::Duration;

use pasconv::conv::{
    conv2d_batched_op_cpu, conv2d_multi_cpu, conv2d_op_cpu, max_abs_diff, BatchedConvOp,
    ConvOp, ConvProblem,
};
use pasconv::coordinator::{BatchConfig, Coordinator, Payload, Response, CPU_LOWERED};
use pasconv::runtime::{default_artifact_dir, Runtime, Tensor};
use pasconv::util::rng::Rng;

fn coordinator_or_skip(cfg: BatchConfig) -> Option<Coordinator> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Coordinator::start(&dir, cfg).expect("coordinator"))
}

fn recv(rx: Receiver<Result<Response, String>>) -> Response {
    rx.recv_timeout(Duration::from_secs(60)).expect("response within 60s").expect("ok response")
}

#[test]
fn conv_request_round_trips_and_matches_oracle() {
    let Some(mut c) = coordinator_or_skip(BatchConfig::default()) else { return };
    let mut rng = Rng::new(11);
    let p = ConvProblem::multi(32, 14, 32, 3);
    let image = Tensor::randn(vec![32, 14, 14], &mut rng);
    let filters = Tensor::randn(vec![32, 32, 3, 3], &mut rng);
    let resp = c
        .submit_wait(Payload::Conv {
            op: ConvOp::dense(p),
            image: image.clone(),
            filters: filters.clone(),
        })
        .unwrap();
    assert_eq!(resp.artifact, "multi_c32_w14_m32_k3");
    assert_eq!(resp.batch_size, 1);
    let want = conv2d_multi_cpu(&p, &image.data, &filters.data);
    assert!(max_abs_diff(&resp.output.data, &want) < 0.1, "numeric mismatch");
    assert!(resp.latency_secs > 0.0);
    // the router warmed the plan table at startup: conv responses carry
    // the tuned-plan advice with zero per-request search
    let advice = resp.plan.as_deref().unwrap_or_default();
    assert!(advice.contains("tuned"), "missing tuned plan advice: {advice:?}");
    c.shutdown();
}

#[test]
fn single_channel_conv_routes() {
    let Some(mut c) = coordinator_or_skip(BatchConfig::default()) else { return };
    let mut rng = Rng::new(12);
    let p = ConvProblem::single(32, 32, 3);
    let image = Tensor::randn(vec![32, 32], &mut rng);
    let filters = Tensor::randn(vec![32, 3, 3], &mut rng);
    let resp = c.submit_wait(Payload::Conv { op: ConvOp::dense(p), image, filters }).unwrap();
    assert_eq!(resp.artifact, "single_w32_m32_k3");
    c.shutdown();
}

#[test]
fn unknown_conv_shape_is_a_clean_error() {
    let Some(mut c) = coordinator_or_skip(BatchConfig::default()) else { return };
    let p = ConvProblem::single(17, 3, 3);
    let err = c
        .submit_wait(Payload::Conv {
            op: ConvOp::dense(p),
            image: Tensor::zeros(vec![17, 17]),
            filters: Tensor::zeros(vec![3, 3, 3]),
        })
        .unwrap_err();
    assert!(err.to_string().contains("no artifact"), "{err}");
    assert_eq!(c.metrics().errors, 1);
    c.shutdown();
}

#[test]
fn cnn_requests_get_batched() {
    // 8 concurrent requests with a generous window must share one batch
    let Some(mut c) = coordinator_or_skip(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(50),
    }) else {
        return;
    };
    let mut rng = Rng::new(13);
    let rxs: Vec<_> = (0..8)
        .map(|_| c.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) }))
        .collect();
    let responses: Vec<Response> = rxs.into_iter().map(recv).collect();
    assert!(responses.iter().all(|r| r.output.shape == vec![1, 10]));
    // the full batch closed by count, not deadline
    assert!(responses.iter().any(|r| r.batch_size == 8), "batch sizes: {:?}",
        responses.iter().map(|r| r.batch_size).collect::<Vec<_>>());
    let m = c.metrics();
    assert!(m.batches_executed < 8, "no batching happened");
    assert!(m.mean_batch_size() > 1.0);
    c.shutdown();
}

#[test]
fn partial_batch_flushes_on_deadline() {
    let Some(mut c) = coordinator_or_skip(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(5),
    }) else {
        return;
    };
    let mut rng = Rng::new(14);
    let rx = c.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) });
    let resp = recv(rx);
    assert_eq!(resp.batch_size, 1, "single request served without waiting forever");
    assert_eq!(resp.output.shape, vec![1, 10]);
    c.shutdown();
}

#[test]
fn batched_results_match_unbatched_runtime() {
    // padding + slicing in the batcher must not corrupt per-request rows
    let Some(mut c) = coordinator_or_skip(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(20),
    }) else {
        return;
    };
    let mut rng = Rng::new(15);
    let images: Vec<Tensor> = (0..3).map(|_| Tensor::randn(vec![1, 28, 28], &mut rng)).collect();
    let rxs: Vec<_> =
        images.iter().map(|im| c.submit(Payload::Cnn { image: im.clone() })).collect();
    let responses: Vec<Response> = rxs.into_iter().map(recv).collect();

    let mut rt = Runtime::new(&default_artifact_dir()).unwrap();
    for (im, resp) in images.iter().zip(&responses) {
        let mut batched = im.clone();
        batched.shape.insert(0, 1); // (1,1,28,28)
        let want = rt.execute("papernet_b1", &[batched]).unwrap();
        let diff = max_abs_diff(&resp.output.data, &want.data);
        assert!(diff < 1e-3, "batched row differs from direct execution: {diff}");
    }
    c.shutdown();
}

#[test]
fn sustained_load_all_served() {
    let Some(mut c) = coordinator_or_skip(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
    }) else {
        return;
    };
    let mut rng = Rng::new(16);
    let n = 64;
    let rxs: Vec<_> = (0..n)
        .map(|_| c.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) }))
        .collect();
    let responses: Vec<Response> = rxs.into_iter().map(recv).collect();
    assert_eq!(responses.len(), n);
    let m = c.metrics();
    assert_eq!(m.responses, n as u64);
    assert_eq!(m.errors, 0);
    assert!(m.latency.quantile(0.5) > 0.0);
    // ids are unique
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n);
    c.shutdown();
}

#[test]
fn shutdown_flushes_pending_work() {
    let Some(mut c) = coordinator_or_skip(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_secs(10), // long window: shutdown must flush
    }) else {
        return;
    };
    let mut rng = Rng::new(17);
    let rx = c.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) });
    std::thread::sleep(Duration::from_millis(20));
    c.shutdown();
    let resp = rx.recv_timeout(Duration::from_secs(5)).expect("flushed at shutdown").unwrap();
    assert_eq!(resp.output.shape, vec![1, 10]);
}

#[test]
fn model_request_serves_graph_report() {
    let Some(mut c) = coordinator_or_skip(BatchConfig::default()) else { return };
    let resp = c.submit_wait(Payload::Model { model: "resnet18".to_string() }).unwrap();
    assert_eq!(resp.artifact, "model:resnet18");
    let m = resp.model.expect("model summary attached");
    assert_eq!(m.model, "resnet18");
    assert!(m.conv_layers >= 10, "conv layers {}", m.conv_layers);
    assert!(m.model_latency_secs > 0.0);
    assert!(m.arena_peak_bytes < m.naive_bytes, "no memory planned");
    // served through the executor's shared device pool: per-tensor
    // granularity never does worse than the whole-arena reservation
    assert!(m.pooled_peak_bytes > 0, "model did not run pooled");
    assert!(
        m.pooled_peak_bytes <= m.arena_peak_bytes,
        "pooled peak {} above arena peak {}",
        m.pooled_peak_bytes,
        m.arena_peak_bytes
    );
    let met = c.metrics();
    assert!(met.pooled_models >= 1, "pool gauges never sampled");
    assert!(met.pool_capacity_bytes > 0);
    assert!(met.pool_peak_bytes as usize >= m.pooled_peak_bytes);
    assert_eq!(met.pool_in_use_bytes, 0, "model execution left bytes resident");
    // output tensor is the per-node latency breakdown
    assert_eq!(resp.output.shape, vec![m.nodes]);
    let sum: f32 = resp.output.data.iter().sum();
    assert!((sum as f64 - m.model_latency_secs).abs() < 1e-3 * m.model_latency_secs);
    // unknown models answer with the registered list, not a hang
    let err = c.submit_wait(Payload::Model { model: "papernet-9000".to_string() }).unwrap_err();
    assert!(err.to_string().contains("not registered"), "{err}");
    c.shutdown();
}

#[test]
fn compatible_convs_coalesce_into_one_micro_batch() {
    // a burst of identical-problem conv requests inside a generous
    // window must share ONE dispatch: same batch id, same plan advice
    let Some(mut c) = coordinator_or_skip(BatchConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(50),
    }) else {
        return;
    };
    let mut rng = Rng::new(31);
    let p = ConvProblem::multi(32, 14, 32, 3);
    let rxs: Vec<_> = (0..4)
        .map(|_| {
            c.submit(Payload::Conv {
                op: ConvOp::dense(p),
                image: Tensor::randn(vec![32, 14, 14], &mut rng),
                filters: Tensor::randn(vec![32, 32, 3, 3], &mut rng),
            })
        })
        .collect();
    let responses: Vec<Response> = rxs.into_iter().map(recv).collect();
    assert!(responses.iter().all(|r| r.batch_size == 4), "batch sizes: {:?}",
        responses.iter().map(|r| r.batch_size).collect::<Vec<_>>());
    let id = responses[0].batch_id.expect("coalesced batch id");
    assert!(responses.iter().all(|r| r.batch_id == Some(id)), "batch ids differ");
    let advice = responses[0].plan.clone().expect("tuned advice");
    assert!(advice.contains("tuned"), "{advice}");
    assert!(
        responses.iter().all(|r| r.plan.as_deref() == Some(advice.as_str())),
        "plan advice differs within the batch"
    );
    let m = c.metrics();
    assert_eq!(m.conv_batches_executed, 1, "one micro-batch dispatch");
    assert!((m.mean_conv_batch_size() - 4.0).abs() < 1e-12);
    c.shutdown();
}

#[test]
fn incompatible_convs_do_not_share_a_batch() {
    let Some(mut c) = coordinator_or_skip(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(5),
    }) else {
        return;
    };
    let mut rng = Rng::new(32);
    let pa = ConvProblem::multi(32, 14, 32, 3);
    let pb = ConvProblem::single(32, 32, 3);
    let ra = c.submit(Payload::Conv {
        op: ConvOp::dense(pa),
        image: Tensor::randn(vec![32, 14, 14], &mut rng),
        filters: Tensor::randn(vec![32, 32, 3, 3], &mut rng),
    });
    let rb = c.submit(Payload::Conv {
        op: ConvOp::dense(pb),
        image: Tensor::randn(vec![32, 32], &mut rng),
        filters: Tensor::randn(vec![32, 3, 3], &mut rng),
    });
    let (ra, rb) = (recv(ra), recv(rb));
    assert_eq!(ra.batch_size, 1);
    assert_eq!(rb.batch_size, 1);
    assert_ne!(ra.batch_id, rb.batch_id, "different shapes must not share a batch");
    assert_ne!(ra.artifact, rb.artifact);
    c.shutdown();
}

#[test]
fn batched_conv_payload_matches_cpu_oracle() {
    let Some(mut c) = coordinator_or_skip(BatchConfig::default()) else { return };
    let mut rng = Rng::new(33);
    let p = ConvProblem::multi(32, 14, 32, 3);
    let b = BatchedConvOp::new(ConvOp::dense(p), 3);
    let images = Tensor::randn(vec![3, 32, 14, 14], &mut rng);
    let filters = Tensor::randn(vec![32, 32, 3, 3], &mut rng);
    let resp = c
        .submit_wait(Payload::BatchedConv {
            batch: b,
            images: images.clone(),
            filters: filters.clone(),
        })
        .unwrap();
    assert_eq!(resp.artifact, "multi_c32_w14_m32_k3");
    assert_eq!(resp.batch_size, 3, "explicit batch reports its image count");
    assert!(resp.batch_id.is_some(), "explicit batches identify their dispatch");
    assert_eq!(resp.output.shape, vec![3, 32, 12, 12]);
    let want = conv2d_batched_op_cpu(&b, &images.data, &filters.data);
    assert!(max_abs_diff(&resp.output.data, &want) < 0.1, "numeric mismatch");
    // malformed batches answer with an error, not a hang
    let err = c
        .submit_wait(Payload::BatchedConv {
            batch: BatchedConvOp::new(ConvOp::dense(p), 2),
            images: Tensor::zeros(vec![3, 32, 14, 14]), // n mismatch
            filters,
        })
        .unwrap_err();
    assert!(err.to_string().contains("batched image shape"), "{err}");
    c.shutdown();
}

#[test]
fn non_dense_op_serves_through_the_cpu_lowering() {
    // a stride-2 'same' op has no PJRT artifact; the coordinator serves
    // it through the exact CPU lowering and says so in the artifact tag
    let Some(mut c) = coordinator_or_skip(BatchConfig::default()) else { return };
    let mut rng = Rng::new(41);
    let op = ConvOp::strided(ConvProblem::multi(8, 14, 16, 3), 2, 1);
    let image = Tensor::randn(vec![8, 14, 14], &mut rng);
    let filters = Tensor::randn(vec![16, 8, 3, 3], &mut rng);
    let resp = c
        .submit_wait(Payload::Conv { op, image: image.clone(), filters: filters.clone() })
        .unwrap();
    assert_eq!(resp.artifact, CPU_LOWERED);
    assert_eq!(resp.output.shape, vec![16, 7, 7]);
    let want = conv2d_op_cpu(&op, &image.data, &filters.data);
    assert_eq!(resp.output.data, want, "CPU lowering must be bit-exact");
    // depthwise batched op too
    let dw = ConvOp::depthwise(8, 14, 3, 1);
    let b = BatchedConvOp::new(dw, 2);
    let images = Tensor::randn(vec![2, 8, 14, 14], &mut rng);
    let dwf = Tensor::randn(vec![8, 1, 3, 3], &mut rng);
    let resp = c
        .submit_wait(Payload::BatchedConv {
            batch: b,
            images: images.clone(),
            filters: dwf.clone(),
        })
        .unwrap();
    assert_eq!(resp.artifact, CPU_LOWERED);
    assert_eq!(resp.output.shape, vec![2, 8, 14, 14]);
    let want = conv2d_batched_op_cpu(&b, &images.data, &dwf.data);
    assert_eq!(resp.output.data, want);
    c.shutdown();
}

#[test]
fn shutdown_under_load_resolves_every_receiver() {
    // a mixed burst followed by immediate shutdown: every receiver must
    // resolve (response or clean error) — nothing hangs, nothing leaks
    let Some(mut c) = coordinator_or_skip(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_secs(5), // long window: shutdown must flush
    }) else {
        return;
    };
    let mut rng = Rng::new(34);
    let p = ConvProblem::multi(64, 7, 64, 3);
    let mut rxs = vec![];
    for i in 0..24 {
        rxs.push(match i % 3 {
            0 => c.submit(Payload::Conv {
                op: ConvOp::dense(p),
                image: Tensor::randn(vec![64, 7, 7], &mut rng),
                filters: Tensor::randn(vec![64, 64, 3, 3], &mut rng),
            }),
            1 => c.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) }),
            _ => c.submit(Payload::Model { model: "alexnet".to_string() }),
        });
    }
    c.shutdown();
    let mut ok = 0;
    for rx in rxs {
        // after shutdown every channel has a terminal answer already
        match rx.recv_timeout(Duration::from_secs(5)) {
            Ok(Ok(_)) => ok += 1,
            Ok(Err(_)) => {} // a clean error is an acceptable resolution
            Err(e) => panic!("receiver unresolved after shutdown: {e}"),
        }
    }
    assert_eq!(ok, 24, "pending work flushed, not dropped");
    let m = c.metrics();
    assert_eq!(m.responses, 24);
    assert_eq!(m.errors, 0);
}

#[test]
fn mixed_conv_and_cnn_traffic() {
    let Some(mut c) = coordinator_or_skip(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
    }) else {
        return;
    };
    let mut rng = Rng::new(18);
    let p = ConvProblem::multi(64, 7, 64, 3);
    let mut rxs = vec![];
    for i in 0..12 {
        if i % 3 == 0 {
            rxs.push(c.submit(Payload::Conv {
                op: ConvOp::dense(p),
                image: Tensor::randn(vec![64, 7, 7], &mut rng),
                filters: Tensor::randn(vec![64, 64, 3, 3], &mut rng),
            }));
        } else {
            rxs.push(c.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) }));
        }
    }
    let responses: Vec<Response> = rxs.into_iter().map(recv).collect();
    assert_eq!(responses.len(), 12);
    let kinds: Vec<&str> = responses.iter().map(|r| r.artifact.as_str()).collect();
    assert!(kinds.iter().any(|k| k.starts_with("multi_")));
    assert!(kinds.iter().any(|k| k.starts_with("papernet")));
    c.shutdown();
}

// ---- multi-tenant pool behavior (artifact-independent) ----

#[test]
fn two_models_stay_resident_on_one_capped_shard() {
    use pasconv::fleet::{Fleet, FleetConfig, Policy};

    let conv = || BatchedConvOp::new(ConvOp::dense(ConvProblem::multi(8, 14, 16, 3)), 4);
    let bytes = conv().footprint_bytes();
    // room for exactly two resident jobs on the single shard
    let mut fleet = Fleet::homogeneous(
        1,
        &pasconv::gpusim::gtx_1080ti(),
        FleetConfig {
            policy: Policy::LeastLoaded,
            queue_bound: 8,
            capacity_bytes: Some(2 * bytes),
        },
    );
    let a = fleet.submit(conv(), Some("alexnet")).expect("first model admitted");
    let b = fleet.submit(conv(), Some("vgg16")).expect("second model admitted");
    assert_eq!((a.device, b.device), (0, 0), "both resident on the one shard");
    let pool = fleet.devices()[0].pool();
    assert_eq!(pool.in_use_slab_bytes(), 2 * bytes, "both footprints held");
    assert!(pool.in_use_slab_bytes() <= pool.capacity(), "cap respected with 2 tenants");

    // a third tenant does not fit: rejected immediately — never queued
    // against memory, never deadlocked
    assert!(fleet.submit(conv(), Some("resnet18")).is_none());
    assert_eq!(fleet.stats.rejected, 1);
    assert_eq!(fleet.stats.mem_rejected, 1, "rejection attributed to memory, not queues");

    // one completion releases its reservation; the shard admits again,
    // reusing the parked slab rather than carving
    fleet.next_completion().expect("head job completes");
    assert_eq!(fleet.devices()[0].pool().in_use_slab_bytes(), bytes);
    assert!(fleet.submit(conv(), Some("resnet18")).is_some(), "freed capacity readmits");
    assert!(fleet.devices()[0].pool().stats.reuse_hits >= 1, "slab reuse after release");
    fleet.drain();
    assert_eq!(fleet.devices()[0].pool().in_use_slab_bytes(), 0, "drain releases everything");
}

#[test]
fn model_execution_shares_a_pool_with_a_resident_tenant_under_cap() {
    use pasconv::backend::dispatch_fused_op_plan;
    use pasconv::fleet::DevicePool;
    use pasconv::graph::{execute_pooled, model_graph, plan_arena, topo_order};

    let spec = pasconv::gpusim::gtx_1080ti();
    let g = model_graph("alexnet").unwrap();
    let floor = plan_arena(&g, &topo_order(&g)).live_peak_bytes();
    let resident_bytes = 8 * 1024 * 1024;
    // cap sized for the model's floor plus one co-resident tenant
    let mut pool = DevicePool::new(floor + resident_bytes);
    let resident = pool.alloc(resident_bytes).expect("tenant takes up residence");

    // the model executes to completion around the resident tenant and
    // the two together never burst the cap
    let (report, plan) = execute_pooled(&g, &spec, dispatch_fused_op_plan, 1, &mut pool)
        .expect("model must fit beside the tenant");
    assert!(report.total_seconds > 0.0);
    assert!(plan.peak_bytes + resident_bytes <= pool.capacity());
    assert!(pool.stats.peak_in_use_slab <= pool.capacity(), "cap held at the high-water mark");
    assert_eq!(pool.in_use_slab_bytes(), resident_bytes, "only the tenant remains");

    // an execution that cannot fit beside the tenant errors out cleanly
    // (its partial allocations rolled back) instead of deadlocking
    let too_big = pool.capacity() / plan.peak_bytes + 2;
    let err = execute_pooled(&g, &spec, dispatch_fused_op_plan, too_big, &mut pool)
        .expect_err("oversized batch must exhaust the pool");
    assert!(err.to_string().contains("exhausted"), "{err}");
    assert_eq!(pool.in_use_slab_bytes(), resident_bytes, "failed run rolled back");

    // and the original workload still runs afterwards — no poisoning
    execute_pooled(&g, &spec, dispatch_fused_op_plan, 1, &mut pool).expect("pool still serves");
    pool.free(resident).unwrap();
    assert_eq!(pool.in_use_slab_bytes(), 0);
}
