//! Property tests for the observability layer.
//!
//! Span-tree invariants over randomized fleet runs: every recorded
//! trace must be well-nested (by id and by time), per-device-lane
//! non-overlapping, monotone in virtual time per event stream, with
//! every accepted request traceable arrival→completion and every
//! rejection carrying a cause attribute that reconciles with the
//! scheduler's own counters.
//!
//! Histogram percentiles vs the exact order statistics: the
//! log-bucketed `coordinator::metrics::Histogram` answers quantiles
//! within its bucket width — an upper edge at most 2x (+fp slop) the
//! exact sample under the histogram's own rank convention, and bounded
//! by `util::stats::percentile_sorted`'s neighboring order statistics
//! once the one-rank convention difference is allowed for.

use pasconv::coordinator::metrics::Histogram;
use pasconv::fleet::{mean_service_secs, offered_load, Fleet, FleetConfig, Policy};
use pasconv::gpusim::gtx_1080ti;
use pasconv::trace::{run_traced, validate_disjoint, Event, Recorder};
use pasconv::util::prop::{check_no_shrink, Config};
use pasconv::util::stats::percentile_sorted;

const BASE: f64 = 1e-6; // Histogram's first bucket edge (metrics.rs)

fn attr_str<'a>(attrs: &'a [(String, pasconv::util::json::Json)], key: &str) -> Option<&'a str> {
    attrs.iter().find(|(k, _)| k == key).map(|(_, v)| match v {
        pasconv::util::json::Json::Str(s) => s.as_str(),
        _ => "",
    })
}

#[test]
fn random_fleet_traces_keep_every_span_invariant() {
    let cfg = Config { cases: 10, seed: 0x7AACE };
    check_no_shrink(
        &cfg,
        |r| {
            let n = r.range_usize(16, 128);
            let overload = 0.5 + 4.0 * r.next_f64();
            let devices = r.range_usize(1, 4);
            let queue_bound = r.range_usize(1, 8);
            let policy = r.range_usize(0, 3);
            let cap_mib = if r.next_f64() < 0.5 { Some(r.range_usize(4, 64)) } else { None };
            let batch = if r.next_f64() < 0.5 { Some([1usize, 2, 4, 8][r.range_usize(0, 3)]) } else { None };
            let seed = r.range_u64(1, u64::MAX / 2);
            (n, overload, devices, queue_bound, policy, cap_mib, batch, seed)
        },
        |&(n, overload, devices, queue_bound, policy, cap_mib, batch, seed)| {
            let g = gtx_1080ti();
            let policy = [
                Policy::RoundRobin,
                Policy::LeastLoaded,
                Policy::LeastLoadedBytes,
                Policy::ModelAffinity,
            ][policy];
            let mut fleet = Fleet::homogeneous(
                devices,
                &g,
                FleetConfig {
                    policy,
                    queue_bound,
                    capacity_bytes: cap_mib.map(|m| m * 1024 * 1024),
                },
            );
            let probe = offered_load(32, 1.0, seed, batch);
            let rate = overload / mean_service_secs(&probe, &g);
            let load = offered_load(n, rate, seed, batch);
            let mut rec = Recorder::new();
            let completions = run_traced(&mut fleet, &load, &mut rec);

            rec.validate().map_err(|e| format!("validate: {e}"))?;
            validate_disjoint(rec.events(), "dev:")
                .map_err(|e| format!("device lanes overlap: {e}"))?;

            let mut requests = 0u64;
            let mut rejects = 0u64;
            let mut mem_rejects = 0u64;
            let mut frees = 0u64;
            for ev in rec.events() {
                match ev {
                    Event::Span(s) if s.name == "request" => requests += 1,
                    Event::Instant(i) if i.name == "reject" => {
                        rejects += 1;
                        match attr_str(&i.attrs, "cause") {
                            Some("memory") => mem_rejects += 1,
                            Some("queue_full") => {}
                            other => return Err(format!("reject cause {other:?}")),
                        }
                    }
                    Event::Instant(i) if i.name == "free" => frees += 1,
                    _ => {}
                }
            }
            if requests != fleet.stats.accepted {
                return Err(format!("{requests} request spans vs {} accepted", fleet.stats.accepted));
            }
            if rejects != fleet.stats.rejected {
                return Err(format!("{rejects} reject instants vs {} rejected", fleet.stats.rejected));
            }
            if mem_rejects != fleet.stats.mem_rejected {
                return Err(format!(
                    "{mem_rejects} memory causes vs {} mem_rejected",
                    fleet.stats.mem_rejected
                ));
            }
            if frees != completions.len() as u64 {
                return Err(format!("{frees} frees vs {} completions", completions.len()));
            }
            // arrival→completion traceability with exact virtual times
            for c in &completions {
                let track = format!("req:{}", c.job);
                let span = rec
                    .events()
                    .iter()
                    .find_map(|e| match e {
                        Event::Span(s) if s.track == track && s.name == "request" => Some(s),
                        _ => None,
                    })
                    .ok_or_else(|| format!("job {} untraceable", c.job))?;
                if span.t0.to_bits() != c.arrival.to_bits()
                    || span.t1.to_bits() != c.finish.to_bits()
                {
                    return Err(format!("job {} span drifted from its completion", c.job));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn histogram_quantiles_are_bucket_width_accurate_vs_exact_percentiles() {
    let cfg = Config { cases: 128, seed: 0x41157 };
    check_no_shrink(
        &cfg,
        |r| {
            let n = r.range_usize(1, 400);
            // log-uniform in [1e-7, 10] s — inside the histogram's
            // resolvable range (top bucket starts at ~33.5 s), with
            // sub-BASE samples exercising the first-bucket clamp
            (0..n).map(|_| 1e-7 * 10f64.powf(8.0 * r.next_f64())).collect::<Vec<f64>>()
        },
        |samples| {
            let mut h = Histogram::default();
            let mut sorted = samples.clone();
            sorted.sort_by(f64::total_cmp);
            for &s in samples {
                h.record(s);
            }
            let n = sorted.len();
            let mut prev_q = 0.0;
            for q in [0.25, 0.5, 0.9, 0.99] {
                let hq = h.quantile(q);
                if hq < prev_q {
                    return Err(format!("quantiles not monotone at q={q}"));
                }
                prev_q = hq;
                // exact value under the histogram's own rank
                // convention (1-indexed ceil(q*n)); bucket upper edge
                // => within (1x, 2x] of the exact sample, fp-tolerant
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
                let exact = sorted[rank].max(BASE);
                if hq <= 0.999999 * exact || hq > 2.000001 * exact {
                    return Err(format!(
                        "q={q}: hist {hq} vs exact {exact} (n={n}) outside (1x, 2x]"
                    ));
                }
                // and against util::stats::percentile_sorted, whose
                // nearest-rank convention can sit one order statistic
                // away: bracket with the neighboring statistics
                let p = percentile_sorted(&sorted, 100.0 * q);
                let p_rank =
                    ((100.0 * q) / 100.0 * (n as f64 - 1.0)).round() as usize;
                let lo = sorted[rank.min(p_rank)].max(BASE);
                let hi = sorted[rank.max(p_rank)].max(BASE);
                let _ = p; // p == sorted[p_rank] by definition
                if hq <= 0.999999 * lo || hq > 2.000001 * hi {
                    return Err(format!(
                        "q={q}: hist {hq} outside bracket ({lo}, {hi}] from percentile_sorted"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn histogram_single_sample_quantile_brackets_the_sample() {
    for v in [5e-7, 1e-6, 3.7e-5, 1e-3, 0.42, 9.9] {
        let mut h = Histogram::default();
        h.record(v);
        for q in [0.01, 0.5, 1.0] {
            let hq = h.quantile(q);
            let vb = v.max(BASE);
            assert!(
                hq > 0.999999 * vb && hq <= 2.000001 * vb,
                "v={v} q={q}: {hq}"
            );
        }
    }
}
