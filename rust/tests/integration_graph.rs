//! Integration: the graph executor end-to-end on all four §4 models —
//! the ISSUE-2 acceptance gates.  Everything here is L1 (simulator +
//! plans + tuner): no artifacts needed, never skipped.

use std::collections::HashSet;

use pasconv::conv::suites;
use pasconv::conv::ConvOp;
use pasconv::gpusim::{gtx_1080ti, simulate};
use pasconv::graph::{execute, model_graph, plan_arena, topo_order, Op, MODEL_NAMES};
use pasconv::plans::{op_plan_for, paper_op_plan_for};

#[test]
fn all_models_execute_end_to_end() {
    let g = gtx_1080ti();
    for name in MODEL_NAMES {
        let graph = model_graph(name).unwrap();
        let paper = execute(&graph, &g, paper_op_plan_for);
        let tuned = execute(&graph, &g, op_plan_for);
        assert!(paper.total_seconds > 0.0 && paper.total_seconds.is_finite(), "{name}");
        assert!(tuned.total_seconds > 0.0 && tuned.total_seconds.is_finite(), "{name}");
        // glue costs are planner-independent, conv costs are where the
        // tuner acts: the tuned graph never loses end to end
        assert!(
            tuned.total_seconds <= paper.total_seconds * (1.0 + 1e-9),
            "{name}: tuned {} > paper {}",
            tuned.total_seconds,
            paper.total_seconds
        );
        assert!(
            (tuned.glue_seconds - paper.glue_seconds).abs() < 1e-12,
            "{name}: glue depends on the conv planner"
        );
        // per-node breakdown covers every node and sums to the total
        assert_eq!(tuned.nodes.len(), graph.len(), "{name}");
        let sum: f64 = tuned.nodes.iter().map(|n| n.seconds).sum();
        assert!((sum - tuned.total_seconds).abs() < 1e-12, "{name}");
    }
}

#[test]
fn arena_peak_strictly_below_naive_sum() {
    // the acceptance bar names resnet18 + inception3a (branch/skip
    // structure); the chain models must save too — tensors die as the
    // network advances
    let mut saved = vec![];
    for name in MODEL_NAMES {
        let graph = model_graph(name).unwrap();
        let plan = plan_arena(&graph, &topo_order(&graph));
        assert!(
            plan.peak_bytes < plan.naive_bytes,
            "{name}: peak {} not below naive {}",
            plan.peak_bytes,
            plan.naive_bytes
        );
        // the DESIGN.md §6 / EXPERIMENTS.md §7 claim: on the §4 models
        // the greedy plan achieves the liveness floor exactly (zero
        // fragmentation)
        assert_eq!(
            plan.peak_bytes,
            plan.live_peak_bytes(),
            "{name}: greedy arena plan fragmented"
        );
        saved.push((name, plan.saved_fraction()));
    }
    for (name, frac) in &saved {
        // every §4 model frees at least a third of the naive footprint
        assert!(*frac > 0.33, "{name}: only {:.0}% saved", 100.0 * frac);
    }
}

#[test]
fn graph_conv_plans_identical_to_standalone() {
    // acceptance: per-node conv plans == plans::op_plan_for standalone
    let g = gtx_1080ti();
    for name in MODEL_NAMES {
        let graph = model_graph(name).unwrap();
        let report = execute(&graph, &g, op_plan_for);
        for nr in &report.nodes {
            let node = graph.node(nr.id);
            if let Op::Conv { conv, epilogue } = &node.op {
                let standalone = op_plan_for(conv, *epilogue, &g);
                assert_eq!(nr.detail, standalone.name, "{name}/{}", node.name);
                let t = simulate(&g, &standalone).seconds;
                assert!(
                    (nr.seconds - t).abs() < 1e-12 * t.max(1e-12),
                    "{name}/{}: graph time {} != standalone {}",
                    node.name,
                    nr.seconds,
                    t
                );
            }
        }
    }
}

#[test]
fn model_layers_match_their_suites() {
    let cases: [(&str, Vec<ConvOp>); 5] = [
        ("alexnet", suites::alexnet()),
        ("vgg16", suites::vgg16()),
        ("resnet18", suites::resnet18()),
        ("inception3a", suites::googlenet_inception3a()),
        ("mobilenet_v1", suites::mobilenet_v1()),
    ];
    for (name, suite) in cases {
        let graph = model_graph(name).unwrap();
        let got: HashSet<ConvOp> = graph.conv_ops().into_iter().collect();
        let want: HashSet<ConvOp> = suite.into_iter().collect();
        assert_eq!(got, want, "{name}");
    }
}

#[test]
fn mobilenet_executes_through_backend_dispatch() {
    // the ISSUE-5 acceptance criterion: MobileNetV1 runs end-to-end
    // through backend::dispatch_fused_op_plan, and the dispatched graph
    // never loses to the tuned-paper-only op path
    let g = gtx_1080ti();
    let graph = model_graph("mobilenet_v1").unwrap();
    let tuned = execute(&graph, &g, op_plan_for);
    let dispatched = execute(&graph, &g, pasconv::backend::dispatch_fused_op_plan);
    assert!(dispatched.total_seconds > 0.0 && dispatched.total_seconds.is_finite());
    assert!(
        dispatched.total_seconds <= tuned.total_seconds * (1.0 + 1e-9),
        "dispatch lost: {} > {}",
        dispatched.total_seconds,
        tuned.total_seconds
    );
    assert_eq!(dispatched.conv_layers, 27);
    // depthwise/strided layers carry their op tags in the report
    assert!(
        dispatched.nodes.iter().any(|n| n.kind == "conv" && n.detail.contains(" g")),
        "no grouped plan visible in the report"
    );
}

#[test]
fn execution_is_deterministic() {
    let g = gtx_1080ti();
    let graph = model_graph("inception3a").unwrap();
    let a = execute(&graph, &g, op_plan_for);
    let b = execute(&graph, &g, op_plan_for);
    let schedule = |r: &pasconv::graph::ModelReport| -> Vec<usize> {
        r.nodes.iter().map(|n| n.id).collect()
    };
    assert_eq!(schedule(&a), schedule(&b));
    assert!((a.total_seconds - b.total_seconds).abs() < 1e-15);
    assert_eq!(a.arena.peak_bytes, b.arena.peak_bytes);
}

#[test]
fn branch_models_overlap_more_than_chains() {
    // structural sanity: the inception cell keeps four branches live at
    // the concat, so its live floor exceeds any single tensor; a chain's
    // floor is about two adjacent tensors
    let graph = model_graph("inception3a").unwrap();
    let plan = plan_arena(&graph, &topo_order(&graph));
    let biggest = plan.placements.iter().map(|p| p.life.bytes).max().unwrap();
    assert!(plan.live_peak_bytes() > 2 * biggest, "branches not simultaneously live");
}
