//! Property-based tests over the plan-space tuner (in-repo harness,
//! util::prop): every tuned plan is legal under `gpusim::occupancy`,
//! never scores worse than the paper's closed-form pick, and the
//! `PlanCache` serialization round-trips whatever the search produces.

use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, simulate, titan_x_maxwell, Loading, MAX_STAGES, MIN_STAGES};
use pasconv::plans::paper_plan_for;
use pasconv::tuner::{self, PlanCache};
use pasconv::util::prop::{check_no_shrink, Config};
use pasconv::util::rng::Rng;

fn any_problem(r: &mut Rng) -> ConvProblem {
    let k = *r.choose(&[1usize, 3, 5]);
    let w = *r.choose(&[7usize, 14, 28, 56, 112, 224, 512]);
    let c = *r.choose(&[1usize, 16, 64, 128, 256, 512]);
    let m = *r.choose(&[16usize, 32, 64, 128, 256, 512]);
    ConvProblem { c, wy: w.max(k), wx: w.max(k), m, k }
}

#[test]
fn prop_tuned_plans_always_legal_per_occupancy() {
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        check_no_shrink(
            &Config { cases: 32, seed: 21 },
            any_problem,
            |p| {
                let plan = tuner::tuned_plan(p, &spec);
                if !tuner::is_legal(&spec, &plan) {
                    return Err(format!(
                        "{} on {}: illegal plan {}",
                        p.label(),
                        spec.name,
                        plan.name
                    ));
                }
                if plan.smem_bytes_per_sm > spec.shared_mem_bytes {
                    return Err(format!("{}: smem {}", p.label(), plan.smem_bytes_per_sm));
                }
                if plan.sms_active < 1 || plan.sms_active > spec.sm_count {
                    return Err(format!("{}: sms {}", p.label(), plan.sms_active));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_tuned_never_worse_than_paper_closed_form() {
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        check_no_shrink(
            &Config { cases: 32, seed: 22 },
            any_problem,
            |p| {
                let tuned = simulate(&spec, &tuner::tuned_plan(p, &spec));
                let paper = simulate(&spec, &paper_plan_for(p, &spec));
                if tuned.seconds > paper.seconds * (1.0 + 1e-9) {
                    return Err(format!(
                        "{} on {}: tuned {} > paper {}",
                        p.label(),
                        spec.name,
                        tuned.seconds,
                        paper.seconds
                    ));
                }
                if !(tuned.seconds.is_finite() && tuned.seconds > 0.0) {
                    return Err(format!("{}: bad time {}", p.label(), tuned.seconds));
                }
                if !(tuned.efficiency > 0.0 && tuned.efficiency <= 1.0) {
                    return Err(format!("{}: bad efficiency {}", p.label(), tuned.efficiency));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_tune_outcome_consistent_with_its_own_report() {
    // Tuned.tuned_cycles must be the simulated cycles of the plan its
    // params rebuild, and the never-lose invariant must hold in the
    // report itself.
    let g = gtx_1080ti();
    check_no_shrink(
        &Config { cases: 24, seed: 23 },
        any_problem,
        |p| {
            let t = tuner::tune(p, &g);
            if t.tuned_cycles > t.paper_cycles * (1.0 + 1e-9) {
                return Err(format!("{}: report says tuned loses", p.label()));
            }
            let rebuilt = simulate(&g, &tuner::build_plan(p, &g, &t.params));
            if (rebuilt.cycles - t.tuned_cycles).abs() > 1e-6 * t.tuned_cycles {
                return Err(format!(
                    "{}: rebuilt {} != reported {}",
                    p.label(),
                    rebuilt.cycles,
                    t.tuned_cycles
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_staged_depth2_cyclic_is_bit_identical() {
    // the multi-stage generalization must be an EXACT no-op at the
    // paper's ping-pong point: same plan, same bits out of simulate
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        check_no_shrink(
            &Config { cases: 48, seed: 25 },
            any_problem,
            |p| {
                for plan in [paper_plan_for(p, &spec), tuner::depth2_tuned_plan(p, &spec)] {
                    let staged = plan.staged(2, Loading::Cyclic);
                    if staged.name != plan.name {
                        return Err(format!("{}: renamed to {}", plan.name, staged.name));
                    }
                    let a = simulate(&spec, &plan).cycles;
                    let b = simulate(&spec, &staged).cycles;
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("{}: {a} != {b} (bitwise)", plan.name));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_staged_cycles_monotone_nonincreasing_in_depth() {
    // under cyclic loading both staged effects help with depth: exposed
    // latency scales 1/(s-1) and the writeback tail 2/s, so cycles can
    // only fall (until the working set no longer fits shared memory)
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        check_no_shrink(
            &Config { cases: 48, seed: 26 },
            any_problem,
            |p| {
                let base = paper_plan_for(p, &spec);
                let mut last = f64::INFINITY;
                for s in MIN_STAGES..=MAX_STAGES {
                    let smem = base.smem_bytes_per_sm + (s - 2) * base.stage_bytes;
                    if smem > spec.shared_mem_bytes {
                        break; // deeper variants are illegal, not slower
                    }
                    let c = simulate(&spec, &base.staged(s, Loading::Cyclic)).cycles;
                    if c > last * (1.0 + 1e-12) {
                        return Err(format!("{}: s={s} cycles {c} > {last}", base.name));
                    }
                    last = c;
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_smem_overflow_panics_cleanly_not_silently() {
    // a staged plan that cannot fit must die with the overflow message,
    // never simulate garbage — forced by inflating stage_bytes so every
    // geometry overflows at depth 3
    let g = gtx_1080ti();
    check_no_shrink(
        &Config { cases: 24, seed: 27 },
        any_problem,
        |p| {
            let mut plan = paper_plan_for(p, &g);
            plan.stage_bytes = g.shared_mem_bytes; // s=3 adds a full budget
            let staged = plan.staged(3, Loading::Cyclic);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                simulate(&g, &staged)
            }));
            let Err(payload) = r else {
                return Err(format!("{}: oversized plan simulated", staged.name));
            };
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            if !msg.contains("stage smem overflow") {
                return Err(format!("{}: wrong panic {msg:?}", staged.name));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_filter_residency_never_loses_to_restreaming() {
    // `batched_resident` only replaces `batched` when every warm round
    // prices at or below its cold twin, so the resident schedule can
    // never lose to re-streaming the filters each image — and when it
    // does engage, the pinned working set must respect shared memory.
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        check_no_shrink(
            &Config { cases: 24, seed: 28 },
            any_problem,
            |p| {
                let plan = tuner::tuned_plan(p, &spec);
                for n in [2usize, 4, 16] {
                    let resident = plan.batched_resident(n, &spec);
                    let restream = plan.batched(n);
                    let a = simulate(&spec, &resident).cycles;
                    let b = simulate(&spec, &restream).cycles;
                    if a > b * (1.0 + 1e-9) {
                        return Err(format!(
                            "{} xb{n} on {}: resident {a} > restream {b}",
                            p.label(),
                            spec.name
                        ));
                    }
                    if resident.smem_bytes_per_sm > spec.shared_mem_bytes {
                        return Err(format!(
                            "{} xb{n}: smem {} over budget {}",
                            p.label(),
                            resident.smem_bytes_per_sm,
                            spec.shared_mem_bytes
                        ));
                    }
                    if resident.name.ends_with("+fr") && !plan.filters_can_stay_resident(&spec)
                    {
                        return Err(format!(
                            "{} xb{n}: residency engaged without legality",
                            p.label()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_batched_resident_cycles_monotone_in_batch() {
    // more images can never cost less: the resident schedule is cold
    // rounds plus (n-1) warm passes, each with non-negative cost
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        check_no_shrink(
            &Config { cases: 24, seed: 29 },
            any_problem,
            |p| {
                let plan = tuner::tuned_plan(p, &spec);
                let mut last = 0.0f64;
                for n in [1usize, 2, 4, 8, 16, 64] {
                    let c = simulate(&spec, &plan.batched_resident(n, &spec)).cycles;
                    if c < last * (1.0 - 1e-12) {
                        return Err(format!(
                            "{} on {}: xb{n} cycles {c} < smaller batch {last}",
                            p.label(),
                            spec.name
                        ));
                    }
                    last = c;
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_residency_stays_legal_under_staged_pipelines() {
    // deepen the ping-pong first (each extra stage buffer eats shared
    // memory), then ask for residency: the qualification must count
    // the staged buffers, never overflow the budget to pin filters
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        check_no_shrink(
            &Config { cases: 24, seed: 30 },
            any_problem,
            |p| {
                let base = paper_plan_for(p, &spec);
                if base.stages != 2 || base.loading != Loading::Cyclic {
                    return Ok(()); // staged() requires the depth-2 cyclic origin
                }
                for s in MIN_STAGES..=MAX_STAGES {
                    let smem = base.smem_bytes_per_sm + (s - 2) * base.stage_bytes;
                    if smem > spec.shared_mem_bytes {
                        break;
                    }
                    let staged = base.staged(s, Loading::Cyclic);
                    let resident = staged.batched_resident(8, &spec);
                    if resident.smem_bytes_per_sm > spec.shared_mem_bytes {
                        return Err(format!(
                            "{} s={s}: resident smem {} over budget {}",
                            p.label(),
                            resident.smem_bytes_per_sm,
                            spec.shared_mem_bytes
                        ));
                    }
                    if resident.name.ends_with("+fr")
                        && !staged.filters_can_stay_resident(&spec)
                    {
                        return Err(format!("{} s={s}: residency without legality", p.label()));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_plan_cache_round_trips_search_results() {
    let g = gtx_1080ti();
    let mut rng = Rng::new(24);
    let mut cache = PlanCache::new();
    let mut problems = vec![];
    for _ in 0..12 {
        let p = any_problem(&mut rng);
        cache.insert(p, &g, tuner::tune(&p, &g));
        problems.push(p);
    }
    let text = cache.to_lines();
    let back = PlanCache::from_lines(&text).expect("parse own serialization");
    assert_eq!(back.len(), cache.len());
    for p in &problems {
        let a = cache.get(p, &g).unwrap();
        let b = back.get(p, &g).unwrap();
        assert_eq!(a, b, "{}", p.label());
    }
    // serialization is a fixed point (deterministic ordering)
    assert_eq!(back.to_lines(), text);
}
