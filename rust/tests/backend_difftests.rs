//! Differential tests for the backend layer: every backend's
//! `execute_reference` must be **bit-identical** (f32 bit patterns, not
//! allclose) to the CPU oracle `conv::cpu::conv2d_multi_cpu` on every
//! problem it `supports()`, and the `supports()` envelopes must reject
//! what they claim to reject — with the dispatcher honoring both.
//!
//! The problem set mirrors the §4 suites structurally — every (kind, K)
//! regime of Fig. 4 / Fig. 5 / the CNN-layer mix, including odd map
//! sizes that force ragged tiles and partial segments — at sizes the
//! plain-loop oracle can run in debug-mode CI (the full-size suite
//! problems exercise the same index arithmetic, just more of it).
//! Timing-side behavior on the *real* suites (legality, never-lose) is
//! simulation-only and runs here at full size.

use pasconv::backend::{self, Dispatcher};
use pasconv::conv::suites::{all_cnn_layers, all_cnn_ops, fig4_suite, fig5_suite};
use pasconv::conv::{
    conv2d_batched_cpu, conv2d_multi_cpu, conv2d_op_cpu, BatchedConv, ConvOp, ConvProblem,
};
use pasconv::gpusim::{gtx_1080ti, simulate, titan_x_maxwell};
use pasconv::tuner;
use pasconv::util::rng::Rng;

/// Suite-shaped problems small enough for the f64 oracle in debug mode:
/// both kernels (C = 1 and C > 1), all three paper K's, maps from 7 to
/// 56 px, non-divisible shapes (13, 27) for ragged tiles/strips.
fn difftest_problems() -> Vec<ConvProblem> {
    vec![
        // Fig. 4 regime: single-channel, inverse (W, M) pairing
        ConvProblem::single(28, 8, 1),
        ConvProblem::single(28, 8, 3),
        ConvProblem::single(28, 4, 5),
        ConvProblem::single(64, 4, 3),
        // Fig. 5 regime: square multi-channel layers, 7..56 px
        ConvProblem::multi(32, 7, 32, 3),
        ConvProblem::multi(8, 14, 16, 1),
        ConvProblem::multi(8, 14, 16, 3),
        ConvProblem::multi(8, 14, 8, 5),
        ConvProblem::multi(16, 28, 16, 3),
        ConvProblem::multi(4, 56, 8, 3),
        // CNN-layer shapes: AlexNet's odd 27/13-px maps, ResNet's K=1
        // projections
        ConvProblem::multi(6, 27, 8, 5),
        ConvProblem::multi(8, 13, 8, 3),
        ConvProblem::multi(8, 28, 16, 1),
    ]
}

fn bit_identical(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn every_backend_bit_identical_to_cpu_oracle_where_supported() {
    let registry = Dispatcher::full();
    let mut rng = Rng::new(0xD1FF);
    for p in difftest_problems() {
        let image = rng.normal_vec(p.map_elems());
        let filters = rng.normal_vec(p.filter_elems());
        let oracle = conv2d_multi_cpu(&p, &image, &filters);
        let mut covered = 0;
        for b in registry.backends() {
            if !b.supports(&p) {
                continue;
            }
            covered += 1;
            let got = b.execute_reference(&p, &image, &filters);
            assert!(
                bit_identical(&got, &oracle),
                "{} diverges from the CPU oracle on {}",
                b.name(),
                p.label()
            );
        }
        // every problem here is valid, so at minimum the paper kernels,
        // the cuDNN proxy, dac17, fft and the CPU anchor must cover it
        assert!(covered >= 6, "{}: only {covered} backends supported it", p.label());
    }
}

#[test]
fn batched_references_are_n_independent_single_runs() {
    let registry = Dispatcher::full();
    let p = ConvProblem::multi(8, 14, 16, 3);
    let b = BatchedConv::new(p, 3);
    let mut rng = Rng::new(0xBA7C);
    let images = rng.normal_vec(b.map_elems());
    let filters = rng.normal_vec(p.filter_elems());
    let oracle = conv2d_batched_cpu(&b, &images, &filters);
    for backend in registry.backends() {
        assert!(backend.supports(&p), "{}", backend.name());
        let got = backend.execute_reference_batched(&b, &images, &filters);
        assert!(
            bit_identical(&got, &oracle),
            "{} batched reference diverges",
            backend.name()
        );
    }
}

#[test]
fn supports_rejections_are_exercised() {
    let registry = Dispatcher::full();
    let k1 = ConvProblem::multi(16, 14, 16, 1);
    let k5 = ConvProblem::multi(16, 14, 16, 5);
    let single = ConvProblem::single(28, 16, 3);
    let invalid = ConvProblem { c: 1, wy: 2, wx: 2, m: 4, k: 3 };

    // winograd F(2x2,3x3): K=3 only
    let wino = registry.backend("winograd").unwrap();
    assert!(!wino.supports(&k1) && !wino.supports(&k5));
    assert!(wino.supports(&ConvProblem::multi(16, 14, 16, 3)));
    // tan128: multi-channel stride-fixed only
    let tan = registry.backend("tan128").unwrap();
    assert!(!tan.supports(&single));
    assert!(tan.supports(&k5));
    // nobody accepts an invalid problem
    for b in registry.backends() {
        assert!(!b.supports(&invalid), "{} accepted K > W", b.name());
    }

    // the candidate sets respect the envelopes...
    let k1_names: Vec<&str> = registry.candidates(&k1).iter().map(|b| b.name()).collect();
    assert!(!k1_names.contains(&"winograd"));
    let single_names: Vec<&str> = registry.candidates(&single).iter().map(|b| b.name()).collect();
    assert!(!single_names.contains(&"tan128"));
    assert!(single_names.contains(&"paper-tuned"));

    // ...and so do actual decisions, everywhere on the real suites
    let g = gtx_1080ti();
    for p in fig4_suite().into_iter().step_by(4).chain(fig5_suite().into_iter().step_by(4)) {
        let d = registry.decide(&p, &g);
        let winner = registry.backend(&d.backend).expect("registered winner");
        assert!(winner.supports(&p), "{} dispatched outside its envelope", d.backend);
    }
}

#[test]
fn dispatch_never_loses_on_the_full_suites() {
    // full-size suites, simulation only — the acceptance gate's test
    // half (the bench `ablation_dispatch` is the reporting half)
    let registry = Dispatcher::full();
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        for p in fig4_suite().into_iter().chain(fig5_suite()).chain(all_cnn_layers()) {
            let d = registry.decide(&p, &spec);
            assert!(
                d.cycles <= d.tuned_cycles * (1.0 + 1e-9),
                "{} on {}: dispatch lost ({} > {})",
                p.label(),
                spec.name,
                d.cycles,
                d.tuned_cycles
            );
        }
    }
}

#[test]
fn op_dispatch_never_loses_to_the_lowered_floor_on_every_model_op() {
    // the ISSUE-5 acceptance gate: every depthwise / strided / padded
    // layer of every model suite (MobileNetV1 included) dispatches at
    // or below the naive lowered paper-tuned floor, on both testbeds
    let registry = Dispatcher::full();
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        for op in all_cnn_ops() {
            let d = registry.decide_op(&op, &spec);
            assert!(
                d.cycles <= d.tuned_cycles * (1.0 + 1e-9),
                "{} on {}: op dispatch lost ({} > {})",
                op.label(),
                spec.name,
                d.cycles,
                d.tuned_cycles
            );
            // the winner's plan is legal and re-simulates to the
            // decided cost
            let plan = registry.backend(&d.backend).unwrap().op_plan(&op, &spec);
            assert!(tuner::is_legal(&spec, &plan), "{}: illegal winner", op.label());
            let r = simulate(&spec, &plan);
            assert!((r.cycles - d.cycles).abs() < 1e-9 * d.cycles, "{}", op.label());
        }
    }
}

/// Op-shaped difftest problems: every lowering axis (pad, stride,
/// groups, depthwise, combinations) at oracle-friendly sizes.
fn difftest_ops() -> Vec<ConvOp> {
    vec![
        ConvOp::same(ConvProblem::multi(4, 13, 6, 3)),
        ConvOp::same(ConvProblem::multi(3, 9, 4, 5)),
        ConvOp::strided(ConvProblem::multi(4, 14, 8, 3), 2, 1),
        ConvOp::strided(ConvProblem::multi(4, 14, 8, 1), 2, 0),
        ConvOp::strided(ConvProblem::single(16, 4, 3), 2, 1),
        ConvOp { core: ConvProblem::multi(6, 10, 9, 3), stride: 1, pad: 0, groups: 3 },
        ConvOp { core: ConvProblem::multi(8, 12, 8, 3), stride: 2, pad: 1, groups: 4 },
        ConvOp::depthwise(6, 14, 3, 1),
        ConvOp::depthwise(8, 13, 3, 2),
        ConvOp::depthwise(4, 9, 5, 1),
    ]
}

#[test]
fn every_backend_op_reference_bit_identical_where_covered() {
    let registry = Dispatcher::full();
    let mut rng = Rng::new(0x0D1F);
    for op in difftest_ops() {
        let image = rng.normal_vec(op.map_elems());
        let filters = rng.normal_vec(op.filter_elems());
        let oracle = conv2d_op_cpu(&op, &image, &filters);
        let mut covered = 0;
        for b in registry.backends() {
            if !b.op_coverage(&op).supported() {
                continue;
            }
            covered += 1;
            let got = b.execute_op_reference(&op, &image, &filters);
            assert!(
                bit_identical(&got, &oracle),
                "{} diverges from the op oracle on {}",
                b.name(),
                op.label()
            );
        }
        // at minimum the paper backends, the cuDNN proxy, fft and the
        // CPU anchor cover every valid op's lowered unit
        assert!(covered >= 5, "{}: only {covered} backends covered it", op.label());
    }
}

#[test]
fn lowered_execution_bit_identical_on_every_model_op() {
    // the acceptance wording verbatim: every depthwise / strided /
    // padded layer's lowered execution is bit-identical to the
    // generalized CPU reference.  Full-size model layers are too big
    // for the debug-mode oracle, so the structural check runs on the
    // suite's smallest instances + scaled-down twins of the rest.
    let registry = Dispatcher::full();
    let tuned = registry.backend("paper-tuned").unwrap();
    let mut rng = Rng::new(0x10E5);
    for op in all_cnn_ops() {
        // scale maps down (geometry preserved) so the oracle stays fast
        let scale = |v: usize, div: usize| (v / div).max(op.core.k).max(1);
        let small = ConvOp {
            core: ConvProblem {
                c: (op.core.c / 16).max(op.groups.min(op.core.c)).max(1),
                wy: scale(op.core.wy, 8),
                wx: scale(op.core.wx, 8),
                m: (op.core.m / 16).max(op.groups.min(op.core.m)).max(1),
                k: op.core.k,
            },
            stride: op.stride,
            pad: op.pad,
            groups: op.groups.min((op.core.c / 16).max(op.groups.min(op.core.c)).max(1)),
        };
        // keep the group split exact: round C/M up to multiples of G
        let g = small.groups;
        let small = ConvOp {
            core: ConvProblem {
                c: small.core.c.div_ceil(g) * g,
                wy: small.core.wy,
                wx: small.core.wx,
                m: small.core.m.div_ceil(g) * g,
                k: small.core.k,
            },
            ..small
        };
        assert!(small.valid(), "{}: scaled twin invalid ({:?})", op.label(), small);
        let image = rng.normal_vec(small.map_elems());
        let filters = rng.normal_vec(small.filter_elems());
        let got = tuned.execute_op_reference(&small, &image, &filters);
        let oracle = conv2d_op_cpu(&small, &image, &filters);
        assert!(bit_identical(&got, &oracle), "{}: lowered execution diverges", op.label());
    }
}

#[test]
fn dispatched_plans_are_legal_and_simulate() {
    let registry = Dispatcher::full();
    let g = gtx_1080ti();
    for p in fig5_suite().into_iter().step_by(3).chain(all_cnn_layers().into_iter().step_by(5)) {
        let d = registry.decide(&p, &g);
        let plan = registry.backend(&d.backend).unwrap().plan(&p, &g);
        assert!(tuner::is_legal(&g, &plan), "{}: illegal winner {}", p.label(), plan.name);
        let r = simulate(&g, &plan);
        assert!(r.seconds > 0.0 && r.seconds.is_finite());
        assert!((r.cycles - d.cycles).abs() < 1e-9 * d.cycles, "{}", p.label());
    }
}

#[test]
fn decision_cache_round_trips_through_plan_cache_files() {
    // dispatch decisions survive save/load exactly (the coordinator's
    // zero-search startup path for v2 cache files)
    let g = gtx_1080ti();
    let registry = Dispatcher::full();
    let mut cache = tuner::PlanCache::new();
    let ops = [
        ConvOp::dense(ConvProblem::multi(256, 56, 256, 3)),
        ConvOp::dense(ConvProblem::multi(256, 14, 256, 1)),
        ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1),
        ConvOp::depthwise(512, 14, 3, 1),
    ];
    for op in ops {
        cache.insert_dispatch(op, &g, registry.decide_op(&op, &g));
    }
    let text = cache.to_lines();
    let back = tuner::PlanCache::from_lines(&text).unwrap();
    assert_eq!(back.dispatch_len(), ops.len());
    for op in ops {
        assert_eq!(back.get_dispatch(&op, &g), cache.get_dispatch(&op, &g), "{}", op.label());
    }
}

#[test]
fn global_dispatch_entry_points_agree_with_registry() {
    let g = gtx_1080ti();
    let p = ConvProblem::multi(64, 28, 64, 3);
    let via_global = backend::dispatched(&p, &g);
    let fresh = Dispatcher::full().decide(&p, &g);
    assert_eq!(via_global, fresh);
    let plan = backend::dispatch_plan(&p, &g);
    assert_eq!(plan.name, Dispatcher::full().backend(&fresh.backend).unwrap().plan(&p, &g).name);
}
