//! Differential tests for the backend layer: every backend's
//! `execute_reference` must be **bit-identical** (f32 bit patterns, not
//! allclose) to the CPU oracle `conv::cpu::conv2d_multi_cpu` on every
//! problem it `supports()`, and the `supports()` envelopes must reject
//! what they claim to reject — with the dispatcher honoring both.
//!
//! The problem set mirrors the §4 suites structurally — every (kind, K)
//! regime of Fig. 4 / Fig. 5 / the CNN-layer mix, including odd map
//! sizes that force ragged tiles and partial segments — at sizes the
//! plain-loop oracle can run in debug-mode CI (the full-size suite
//! problems exercise the same index arithmetic, just more of it).
//! Timing-side behavior on the *real* suites (legality, never-lose) is
//! simulation-only and runs here at full size.

use pasconv::backend::{self, Dispatcher};
use pasconv::conv::suites::{all_cnn_layers, fig4_suite, fig5_suite};
use pasconv::conv::{conv2d_batched_cpu, conv2d_multi_cpu, BatchedConv, ConvProblem};
use pasconv::gpusim::{gtx_1080ti, simulate, titan_x_maxwell};
use pasconv::tuner;
use pasconv::util::rng::Rng;

/// Suite-shaped problems small enough for the f64 oracle in debug mode:
/// both kernels (C = 1 and C > 1), all three paper K's, maps from 7 to
/// 56 px, non-divisible shapes (13, 27) for ragged tiles/strips.
fn difftest_problems() -> Vec<ConvProblem> {
    vec![
        // Fig. 4 regime: single-channel, inverse (W, M) pairing
        ConvProblem::single(28, 8, 1),
        ConvProblem::single(28, 8, 3),
        ConvProblem::single(28, 4, 5),
        ConvProblem::single(64, 4, 3),
        // Fig. 5 regime: square multi-channel layers, 7..56 px
        ConvProblem::multi(32, 7, 32, 3),
        ConvProblem::multi(8, 14, 16, 1),
        ConvProblem::multi(8, 14, 16, 3),
        ConvProblem::multi(8, 14, 8, 5),
        ConvProblem::multi(16, 28, 16, 3),
        ConvProblem::multi(4, 56, 8, 3),
        // CNN-layer shapes: AlexNet's odd 27/13-px maps, ResNet's K=1
        // projections
        ConvProblem::multi(6, 27, 8, 5),
        ConvProblem::multi(8, 13, 8, 3),
        ConvProblem::multi(8, 28, 16, 1),
    ]
}

fn bit_identical(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn every_backend_bit_identical_to_cpu_oracle_where_supported() {
    let registry = Dispatcher::full();
    let mut rng = Rng::new(0xD1FF);
    for p in difftest_problems() {
        let image = rng.normal_vec(p.map_elems());
        let filters = rng.normal_vec(p.filter_elems());
        let oracle = conv2d_multi_cpu(&p, &image, &filters);
        let mut covered = 0;
        for b in registry.backends() {
            if !b.supports(&p) {
                continue;
            }
            covered += 1;
            let got = b.execute_reference(&p, &image, &filters);
            assert!(
                bit_identical(&got, &oracle),
                "{} diverges from the CPU oracle on {}",
                b.name(),
                p.label()
            );
        }
        // every problem here is valid, so at minimum the paper kernels,
        // the cuDNN proxy, dac17, fft and the CPU anchor must cover it
        assert!(covered >= 6, "{}: only {covered} backends supported it", p.label());
    }
}

#[test]
fn batched_references_are_n_independent_single_runs() {
    let registry = Dispatcher::full();
    let p = ConvProblem::multi(8, 14, 16, 3);
    let b = BatchedConv::new(p, 3);
    let mut rng = Rng::new(0xBA7C);
    let images = rng.normal_vec(b.map_elems());
    let filters = rng.normal_vec(p.filter_elems());
    let oracle = conv2d_batched_cpu(&b, &images, &filters);
    for backend in registry.backends() {
        assert!(backend.supports(&p), "{}", backend.name());
        let got = backend.execute_reference_batched(&b, &images, &filters);
        assert!(
            bit_identical(&got, &oracle),
            "{} batched reference diverges",
            backend.name()
        );
    }
}

#[test]
fn supports_rejections_are_exercised() {
    let registry = Dispatcher::full();
    let k1 = ConvProblem::multi(16, 14, 16, 1);
    let k5 = ConvProblem::multi(16, 14, 16, 5);
    let single = ConvProblem::single(28, 16, 3);
    let invalid = ConvProblem { c: 1, wy: 2, wx: 2, m: 4, k: 3 };

    // winograd F(2x2,3x3): K=3 only
    let wino = registry.backend("winograd").unwrap();
    assert!(!wino.supports(&k1) && !wino.supports(&k5));
    assert!(wino.supports(&ConvProblem::multi(16, 14, 16, 3)));
    // tan128: multi-channel stride-fixed only
    let tan = registry.backend("tan128").unwrap();
    assert!(!tan.supports(&single));
    assert!(tan.supports(&k5));
    // nobody accepts an invalid problem
    for b in registry.backends() {
        assert!(!b.supports(&invalid), "{} accepted K > W", b.name());
    }

    // the candidate sets respect the envelopes...
    let k1_names: Vec<&str> = registry.candidates(&k1).iter().map(|b| b.name()).collect();
    assert!(!k1_names.contains(&"winograd"));
    let single_names: Vec<&str> = registry.candidates(&single).iter().map(|b| b.name()).collect();
    assert!(!single_names.contains(&"tan128"));
    assert!(single_names.contains(&"paper-tuned"));

    // ...and so do actual decisions, everywhere on the real suites
    let g = gtx_1080ti();
    for p in fig4_suite().into_iter().step_by(4).chain(fig5_suite().into_iter().step_by(4)) {
        let d = registry.decide(&p, &g);
        let winner = registry.backend(&d.backend).expect("registered winner");
        assert!(winner.supports(&p), "{} dispatched outside its envelope", d.backend);
    }
}

#[test]
fn dispatch_never_loses_on_the_full_suites() {
    // full-size suites, simulation only — the acceptance gate's test
    // half (the bench `ablation_dispatch` is the reporting half)
    let registry = Dispatcher::full();
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        for p in fig4_suite().into_iter().chain(fig5_suite()).chain(all_cnn_layers()) {
            let d = registry.decide(&p, &spec);
            assert!(
                d.cycles <= d.tuned_cycles * (1.0 + 1e-9),
                "{} on {}: dispatch lost ({} > {})",
                p.label(),
                spec.name,
                d.cycles,
                d.tuned_cycles
            );
        }
    }
}

#[test]
fn dispatched_plans_are_legal_and_simulate() {
    let registry = Dispatcher::full();
    let g = gtx_1080ti();
    for p in fig5_suite().into_iter().step_by(3).chain(all_cnn_layers().into_iter().step_by(5)) {
        let d = registry.decide(&p, &g);
        let plan = registry.backend(&d.backend).unwrap().plan(&p, &g);
        assert!(tuner::is_legal(&g, &plan), "{}: illegal winner {}", p.label(), plan.name);
        let r = simulate(&g, &plan);
        assert!(r.seconds > 0.0 && r.seconds.is_finite());
        assert!((r.cycles - d.cycles).abs() < 1e-9 * d.cycles, "{}", p.label());
    }
}

#[test]
fn decision_cache_round_trips_through_plan_cache_files() {
    // dispatch decisions survive save/load exactly (the coordinator's
    // zero-search startup path for v2 cache files)
    let g = gtx_1080ti();
    let registry = Dispatcher::full();
    let mut cache = tuner::PlanCache::new();
    for p in [ConvProblem::multi(256, 56, 256, 3), ConvProblem::multi(256, 14, 256, 1)] {
        cache.insert_dispatch(p, &g, registry.decide(&p, &g));
    }
    let text = cache.to_lines();
    let back = tuner::PlanCache::from_lines(&text).unwrap();
    assert_eq!(back.dispatch_len(), 2);
    for p in [ConvProblem::multi(256, 56, 256, 3), ConvProblem::multi(256, 14, 256, 1)] {
        assert_eq!(back.get_dispatch(&p, &g), cache.get_dispatch(&p, &g), "{}", p.label());
    }
}

#[test]
fn global_dispatch_entry_points_agree_with_registry() {
    let g = gtx_1080ti();
    let p = ConvProblem::multi(64, 28, 64, 3);
    let via_global = backend::dispatched(&p, &g);
    let fresh = Dispatcher::full().decide(&p, &g);
    assert_eq!(via_global, fresh);
    let plan = backend::dispatch_plan(&p, &g);
    assert_eq!(plan.name, Dispatcher::full().backend(&fresh.backend).unwrap().plan(&p, &g).name);
}
