//! Property-based tests (in-repo harness, util::prop — proptest is not
//! in the offline vendor set) over the simulator, the analytic model and
//! the coordinator's batching policy.

use std::time::{Duration, Instant};

use pasconv::analytic::multi::{choose as choose_sf, working_set_bytes};
use pasconv::analytic::single::{choose as choose_single, d1_bytes, d2_bytes, th1, th2};
use pasconv::conv::{conv2d_multi_cpu, ConvProblem};
use pasconv::coordinator::{BatchConfig, Batcher};
use pasconv::gpusim::memory::{latency_exposure, segment_efficiency, transfer_cycles, AccessConfig};
use pasconv::gpusim::pipeline::{combined_efficiency, simulate_pipeline, ExecConfig, Round};
use pasconv::gpusim::{gtx_1080ti, simulate, titan_x_maxwell};
use pasconv::plans::plan_for;
use pasconv::util::prop::{check_no_shrink, Config};
use pasconv::util::rng::Rng;

fn any_problem(r: &mut Rng) -> ConvProblem {
    let k = *r.choose(&[1usize, 3, 5]);
    let w = *r.choose(&[7usize, 14, 28, 56, 112, 224, 512]);
    let c = *r.choose(&[1usize, 16, 64, 128, 256, 512]);
    let m = *r.choose(&[16usize, 32, 64, 128, 256, 512]);
    ConvProblem { c, wy: w.max(k), wx: w.max(k), m, k }
}

// ---------------------------------------------------------------------------
// simulator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_segment_efficiency_bounded_and_unimodal_at_multiples() {
    check_no_shrink(
        &Config { cases: 512, seed: 1 },
        |r| r.range_usize(1, 4096),
        |&s| {
            let e = segment_efficiency(s);
            if !(e > 0.0 && e <= 1.0) {
                return Err(format!("eff({s}) = {e} out of (0,1]"));
            }
            // a multiple of 32 never loses to any smaller segment
            let m32 = s / 32 * 32;
            if m32 >= 32 && segment_efficiency(m32) + 1e-12 < e {
                return Err(format!("eff({m32}) < eff({s})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_transfer_cycles_monotone_in_bytes() {
    let g = gtx_1080ti();
    check_no_shrink(
        &Config { cases: 256, seed: 2 },
        |r| (r.range_usize(32, 4096), r.range_u64(1, 1_000_000) as f64),
        |&(seg, bytes)| {
            let cfg = AccessConfig { segment_bytes: seg, sms_active: 28, threads_per_sm: 1024 };
            let a = transfer_cycles(&g, &cfg, bytes);
            let b = transfer_cycles(&g, &cfg, bytes * 2.0);
            if b <= a {
                return Err(format!("2x bytes not slower: {a} vs {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_latency_exposure_in_unit_interval_and_monotone() {
    let g = gtx_1080ti();
    check_no_shrink(
        &Config { cases: 256, seed: 3 },
        |r| (r.range_u64(1, 4096) as u32, r.range_u64(1, 100_000) as f64),
        |&(threads, bytes)| {
            let e = latency_exposure(&g, threads, bytes);
            if !(0.0..=1.0).contains(&e) {
                return Err(format!("exposure {e}"));
            }
            // more bytes in flight can only reduce exposure
            let e2 = latency_exposure(&g, threads, bytes * 2.0);
            if e2 > e + 1e-12 {
                return Err(format!("exposure rose with volume: {e} -> {e2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pipeline_total_bounded() {
    // max(sum loads, sum computes) <= total <= prologue + sum both
    let g = gtx_1080ti();
    check_no_shrink(
        &Config { cases: 128, seed: 4 },
        |r| {
            let n = r.range_usize(1, 24);
            (0..n)
                .map(|_| {
                    Round::new(
                        r.range_u64(0, 200_000) as f64,
                        *r.choose(&[32usize, 64, 128]),
                        r.range_u64(0, 2_000_000) as f64,
                    )
                })
                .collect::<Vec<Round>>()
        },
        |rounds| {
            let cfg = ExecConfig::new(&g, 1024);
            let res = simulate_pipeline(&g, &cfg, rounds);
            let lo = res.load_cycles_sum.max(res.compute_cycles_sum);
            let hi = res.load_cycles_sum
                + res.compute_cycles_sum
                + cfg.launch_overhead_cycles
                + g.mem_latency_cycles as f64;
            if res.total_cycles + 1e-6 < lo {
                return Err(format!("total {} < lower bound {lo}", res.total_cycles));
            }
            if res.total_cycles > hi + 1e-6 {
                return Err(format!("total {} > upper bound {hi}", res.total_cycles));
            }
            if res.stall_cycles < 0.0 {
                return Err("negative stall".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_combined_efficiency_between_min_and_max() {
    check_no_shrink(
        &Config { cases: 256, seed: 5 },
        |r| {
            let n = r.range_usize(1, 5);
            (0..n)
                .map(|_| (r.range_u64(1, 100_000) as f64, 0.05 + 0.95 * r.next_f64()))
                .collect::<Vec<(f64, f64)>>()
        },
        |streams| {
            let e = combined_efficiency(streams);
            let lo = streams.iter().map(|&(_, x)| x).fold(f64::INFINITY, f64::min);
            let hi = streams.iter().map(|&(_, x)| x).fold(0.0, f64::max);
            if e < lo - 1e-9 || e > hi + 1e-9 {
                return Err(format!("combined {e} outside [{lo}, {hi}]"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simulated_plans_sane_on_random_problems() {
    // any valid problem simulates to a finite positive time with
    // efficiency in (0, 1] on both GPUs
    for spec in [gtx_1080ti(), titan_x_maxwell()] {
        check_no_shrink(
            &Config { cases: 48, seed: 6 },
            any_problem,
            |p| {
                let r = simulate(&spec, &plan_for(p, &spec));
                if !(r.seconds.is_finite() && r.seconds > 0.0) {
                    return Err(format!("{}: bad time {}", p.label(), r.seconds));
                }
                if !(r.efficiency > 0.0 && r.efficiency <= 1.0) {
                    return Err(format!("{}: bad efficiency {}", p.label(), r.efficiency));
                }
                Ok(())
            },
        );
    }
}

// ---------------------------------------------------------------------------
// analytic-model invariants (§3.1, §3.2)
// ---------------------------------------------------------------------------

#[test]
fn prop_single_choice_respects_paper_bounds() {
    let g = gtx_1080ti();
    check_no_shrink(
        &Config { cases: 96, seed: 7 },
        |r| {
            let mut p = any_problem(r);
            p.c = 1;
            p
        },
        |p| {
            let c = choose_single(p, &g);
            if c.p < 1 || c.p > p.wy || c.q < 1 || c.q > p.m {
                return Err(format!("{}: divisors out of range P={} Q={}", p.label(), c.p, c.q));
            }
            if c.p != 1 && c.q != 1 {
                return Err("step 4 must reset the losing divisor to 1".into());
            }
            if c.uses_prefetch {
                let (d, th) = match c.method {
                    pasconv::analytic::SingleMethod::FilterSplit => (c.d1_bytes, c.th1),
                    pasconv::analytic::SingleMethod::MapSplit => (c.d2_bytes, c.th2),
                };
                if d > g.shared_mem_bytes as usize {
                    return Err(format!("{}: D={} > S_shared", p.label(), d));
                }
                if th < g.n_fma() {
                    return Err(format!("{}: Th={} < N_FMA", p.label(), th));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_single_formulas_monotone_in_divisor() {
    let g = gtx_1080ti();
    check_no_shrink(
        &Config { cases: 96, seed: 8 },
        |r| {
            let mut p = any_problem(r);
            p.c = 1;
            (p, r.range_usize(1, 16))
        },
        |&(p, d)| {
            if d + 1 > p.wy.min(p.m) {
                return Ok(());
            }
            if d1_bytes(&p, &g, d + 1) > d1_bytes(&p, &g, d)
                || d2_bytes(&p, &g, d + 1) > d2_bytes(&p, &g, d)
            {
                return Err(format!("{}: D grew with divisor {d}", p.label()));
            }
            if th1(&p, &g, d + 1) > th1(&p, &g, d) || th2(&p, &g, d + 1) > th2(&p, &g, d) {
                return Err(format!("{}: Th grew with divisor {d}", p.label()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stride_fixed_choice_fits_half_smem() {
    let g = gtx_1080ti();
    check_no_shrink(
        &Config { cases: 96, seed: 9 },
        |r| {
            let mut p = any_problem(r);
            if p.c == 1 {
                p.c = 64;
            }
            (p, *r.choose(&[32usize, 64]))
        },
        |&(p, s)| {
            let c = choose_sf(&p, &g, s);
            if c.smem_bytes > g.shared_mem_bytes as usize / 2 {
                return Err(format!("{} S={s}: working set {}", p.label(), c.smem_bytes));
            }
            if c.smem_bytes != working_set_bytes(s, c.wx_prime, c.m_prime, p.k) {
                return Err("working-set accounting inconsistent".into());
            }
            if c.wx_prime % 32 != 0 {
                return Err(format!("W'x={} not a 128-B multiple", c.wx_prime));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// CPU conv oracle + batcher properties
// ---------------------------------------------------------------------------

#[test]
fn prop_cpu_conv_linear_in_image() {
    check_no_shrink(
        &Config { cases: 24, seed: 10 },
        |r| {
            let k = *r.choose(&[1usize, 2, 3]);
            let w = r.range_usize(k, 10);
            let c = r.range_usize(1, 4);
            let m = r.range_usize(1, 4);
            let p = ConvProblem { c, wy: w, wx: w, m, k };
            let img = r.normal_vec(p.map_elems());
            let flt = r.normal_vec(p.filter_elems());
            (p, img, flt)
        },
        |(p, img, flt)| {
            let out = conv2d_multi_cpu(p, img, flt);
            let img2: Vec<f32> = img.iter().map(|x| 3.0 * x).collect();
            let out2 = conv2d_multi_cpu(p, &img2, flt);
            for (a, b) in out.iter().zip(&out2) {
                if (3.0 * a - b).abs() > 1e-3 * (1.0 + a.abs() * 3.0) {
                    return Err(format!("linearity broken: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_never_exceeds_max_and_never_drops() {
    check_no_shrink(
        &Config { cases: 128, seed: 11 },
        |r| {
            let max_batch = r.range_usize(1, 10);
            let n = r.range_usize(1, 50);
            // event stream: (item id, ms offset)
            let events: Vec<(usize, u64)> =
                (0..n).map(|i| (i, r.range_u64(0, 30))).collect();
            (max_batch, events)
        },
        |(max_batch, events)| {
            let t0 = Instant::now();
            let mut b = Batcher::new(BatchConfig {
                max_batch: *max_batch,
                max_wait: Duration::from_millis(10),
            });
            let mut seen = vec![];
            let mut sorted = events.clone();
            sorted.sort_by_key(|&(_, t)| t);
            for &(id, ms) in &sorted {
                let now = t0 + Duration::from_millis(ms);
                if let Some(batch) = b.poll(now) {
                    if batch.len() > *max_batch {
                        return Err("poll batch too big".into());
                    }
                    seen.extend(batch);
                }
                if let Some(batch) = b.push(id, now) {
                    if batch.len() != *max_batch {
                        return Err(format!("push closed a batch of {}", batch.len()));
                    }
                    seen.extend(batch);
                }
            }
            if let Some(batch) = b.take() {
                seen.extend(batch);
            }
            if seen.len() != events.len() {
                return Err(format!("dropped items: {} of {}", seen.len(), events.len()));
            }
            seen.sort();
            for (i, &id) in seen.iter().enumerate() {
                if i != id {
                    return Err("duplicate or missing id".into());
                }
            }
            Ok(())
        },
    );
}
