//! Stateful property tests for the fleet scheduler, modeled on
//! proptest-stateful's plan/check loop: random submit / complete /
//! drain / advance command sequences run against the real `Fleet` while
//! an in-test reference model replays every transition independently.
//!
//! Pinned invariants:
//!  * every accepted request completes exactly once (never lost, never
//!    duplicated), across completes, drains and interleaved submits;
//!  * no device ever exceeds its queue bound, and admission rejects
//!    exactly when every candidate queue is at the bound;
//!  * least-loaded never picks a strictly worse device: the chosen
//!    shard's predicted completion is minimal among non-full shards;
//!  * round-robin visits devices cyclically (skipping full queues) and
//!    model-affinity stays pinned, spilling only under pressure;
//!  * placements and completions match the reference model exactly
//!    (same start/finish arithmetic, same event order, same clock).
//!
//! Plus the differential batching properties the batch-aware serving
//! path rests on: the batched CPU reference is bit-identical to `n`
//! independent single-image runs, and batched predicted cycles are
//! monotone in `n`, amortizing (<= n independent launches) and bounded
//! below by the n/devices-scaled single-image cost at the fleet level.
//!
//! Seed and case count are fixed (CI runs this file directly) so the
//! runtime stays bounded and failures replay deterministically.

use std::collections::{HashMap, HashSet, VecDeque};

use pasconv::conv::{
    conv2d_batched_cpu, conv2d_multi_cpu, BatchedConv, BatchedConvOp, ConvOp, ConvProblem,
};
use pasconv::fleet::{Fleet, FleetConfig, Policy};
use pasconv::gpusim::{gtx_1080ti, titan_x_maxwell, GpuSpec};
use pasconv::plans;
use pasconv::util::prop::{check, Config};
use pasconv::util::rng::Rng;

/// Fixed seed + case count: bounded runtime, deterministic replays.
fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0xF1EE7D }
}

/// Small problems (fast to tune once per process) that still cover both
/// kernels.
fn templates() -> Vec<ConvProblem> {
    vec![
        ConvProblem::multi(8, 14, 16, 3),
        ConvProblem::single(32, 16, 3),
        ConvProblem::multi(16, 7, 32, 3),
    ]
}

/// Fleet job templates: the dense problems plus real op-layer jobs
/// (a stride-2 downsampler and a depthwise 3x3) — the scheduler prices
/// all of them through the same per-shard op dispatcher.
fn op_templates() -> Vec<ConvOp> {
    let mut out: Vec<ConvOp> = templates().into_iter().map(ConvOp::dense).collect();
    out.push(ConvOp::strided(ConvProblem::multi(8, 28, 16, 3), 2, 1));
    out.push(ConvOp::depthwise(16, 14, 3, 1));
    out
}

const MODELS: [&str; 3] = ["alexnet", "resnet18", "vgg16"];

#[derive(Clone, Debug)]
enum Cmd {
    Submit { template: usize, n: usize, model: Option<usize> },
    Complete,
    Drain,
    Advance { dt_ms: u64 },
}

#[derive(Clone, Debug)]
struct Case {
    policy: Policy,
    devices: usize,
    hetero: bool,
    queue_bound: usize,
    cmds: Vec<Cmd>,
}

fn gen_case(rng: &mut Rng) -> Case {
    let policy = *rng.choose(&[Policy::RoundRobin, Policy::LeastLoaded, Policy::ModelAffinity]);
    let devices = rng.range_usize(1, 4);
    let hetero = rng.range_usize(0, 1) == 1;
    let queue_bound = rng.range_usize(1, 4);
    let n_cmds = rng.range_usize(10, 40);
    let cmds = (0..n_cmds)
        .map(|_| match rng.range_usize(0, 9) {
            0..=5 => Cmd::Submit {
                template: rng.range_usize(0, op_templates().len() - 1),
                n: [1, 2, 4, 8][rng.range_usize(0, 3)],
                model: match rng.range_usize(0, 3) {
                    0 => None,
                    i => Some(i - 1),
                },
            },
            6 | 7 => Cmd::Complete,
            8 => Cmd::Advance { dt_ms: rng.range_u64(1, 50) },
            _ => Cmd::Drain,
        })
        .collect();
    Case { policy, devices, hetero, queue_bound, cmds }
}

/// Shrink a failing case by truncating the command tail.
fn shrink_case(c: &Case) -> Vec<Case> {
    let mut out = vec![];
    if c.cmds.len() > 1 {
        out.push(Case { cmds: c.cmds[..c.cmds.len() / 2].to_vec(), ..c.clone() });
        out.push(Case { cmds: c.cmds[..c.cmds.len() - 1].to_vec(), ..c.clone() });
    }
    out
}

fn specs_for(c: &Case) -> Vec<GpuSpec> {
    (0..c.devices)
        .map(|i| if c.hetero && i % 2 == 1 { titan_x_maxwell() } else { gtx_1080ti() })
        .collect()
}

/// The reference model: an independent replay of the fleet's contract.
struct RefModel {
    now: f64,
    tails: Vec<f64>,
    queues: Vec<VecDeque<(u64, f64)>>, // (job id, finish)
    bound: usize,
    rr_cursor: usize,
    pins: HashMap<usize, usize>, // model idx -> device
    accepted: HashSet<u64>,
    completed: HashSet<u64>,
    next_job: u64,
}

impl RefModel {
    fn new(devices: usize, bound: usize) -> RefModel {
        RefModel {
            now: 0.0,
            tails: vec![0.0; devices],
            queues: vec![VecDeque::new(); devices],
            bound,
            rr_cursor: 0,
            pins: HashMap::new(),
            accepted: HashSet::new(),
            completed: HashSet::new(),
            next_job: 1,
        }
    }

    fn full(&self, d: usize) -> bool {
        self.queues[d].len() >= self.bound
    }

    fn completion_if_placed(&self, d: usize, service: &[f64]) -> f64 {
        self.tails[d].max(self.now) + service[d]
    }

    fn least_loaded(&self, service: &[f64]) -> Option<usize> {
        (0..self.queues.len())
            .filter(|&d| !self.full(d))
            .min_by(|&a, &b| {
                self.completion_if_placed(a, service)
                    .partial_cmp(&self.completion_if_placed(b, service))
                    .unwrap()
                    .then(a.cmp(&b))
            })
    }

    /// The device the policy must choose, mirroring the scheduler.
    /// Affinity pins are recorded by the caller on ACCEPTED placements
    /// only — a rejected first sight must not pin.
    fn expected_pick(&mut self, policy: Policy, model: Option<usize>, service: &[f64])
        -> Option<usize> {
        match policy {
            Policy::RoundRobin => {
                let n = self.queues.len();
                let pick = (0..n).map(|i| (self.rr_cursor + i) % n).find(|&d| !self.full(d));
                if let Some(d) = pick {
                    self.rr_cursor = (d + 1) % n;
                }
                pick
            }
            Policy::LeastLoaded => self.least_loaded(service),
            Policy::ModelAffinity => match model.and_then(|m| self.pins.get(&m).copied()) {
                None => self.least_loaded(service),
                Some(pin) if !self.full(pin) => Some(pin),
                Some(_) => self.least_loaded(service),
            },
        }
    }

    /// Earliest head-of-queue finish (tie -> lowest device).
    fn expected_completion(&self) -> Option<(usize, u64, f64)> {
        (0..self.queues.len())
            .filter_map(|d| self.queues[d].front().map(|&(id, f)| (d, id, f)))
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap().then(a.0.cmp(&b.0)))
    }
}

/// Run one generated case: real fleet vs reference model, invariant
/// checks after every command.
fn run_case(case: &Case) -> Result<(), String> {
    let specs = specs_for(case);
    let mut fleet = Fleet::new(
        specs.clone(),
        FleetConfig { policy: case.policy, queue_bound: case.queue_bound },
    );
    let mut model = RefModel::new(case.devices, case.queue_bound);
    let temps = op_templates();

    let check_completion = |fleet: &mut Fleet, model: &mut RefModel| -> Result<(), String> {
        let expect = model.expected_completion();
        let got = fleet.next_completion();
        match (expect, got) {
            (None, None) => Ok(()),
            (Some((d, id, f)), Some(c)) => {
                if c.device != d || c.job != id || (c.finish - f).abs() > 0.0 {
                    return Err(format!(
                        "completion mismatch: got job {} dev {} finish {}, want {id}/{d}/{f}",
                        c.job, c.device, c.finish
                    ));
                }
                if !model.completed.insert(id) {
                    return Err(format!("job {id} completed twice"));
                }
                if !model.accepted.contains(&id) {
                    return Err(format!("job {id} completed but never accepted"));
                }
                model.queues[d].pop_front();
                model.now = model.now.max(f);
                Ok(())
            }
            (e, g) => Err(format!("completion disagreement: want {e:?}, fleet {:?}",
                g.map(|c| (c.device, c.job, c.finish)))),
        }
    };

    for (step, cmd) in case.cmds.iter().enumerate() {
        match *cmd {
            Cmd::Submit { template, n, model: m } => {
                let conv = BatchedConvOp::new(temps[template], n);
                let service: Vec<f64> =
                    (0..case.devices).map(|d| fleet.predicted_service(&conv, d)).collect();
                let tag = m.map(|i| MODELS[i]);
                let expect = model.expected_pick(case.policy, m, &service);
                let got = fleet.submit(conv, tag);
                match (expect, got) {
                    (None, None) => {
                        if !(0..case.devices).all(|d| model.full(d)) {
                            return Err(format!("step {step}: rejected with free capacity"));
                        }
                    }
                    (Some(d), Some(p)) => {
                        if p.device != d {
                            return Err(format!(
                                "step {step}: placed on {} but policy {:?} demands {d}",
                                p.device, case.policy
                            ));
                        }
                        // least-loaded minimality: no non-full shard was
                        // strictly better than the chosen one
                        if case.policy == Policy::LeastLoaded {
                            let chosen = model.completion_if_placed(d, &service);
                            for e in 0..case.devices {
                                if !model.full(e)
                                    && model.completion_if_placed(e, &service) < chosen - 1e-12
                                {
                                    return Err(format!(
                                        "step {step}: least-loaded picked {d} over busier-free {e}"
                                    ));
                                }
                            }
                        }
                        let start = model.tails[d].max(model.now);
                        let finish = start + service[d];
                        if (p.start - start).abs() > 0.0 || (p.finish - finish).abs() > 0.0 {
                            return Err(format!(
                                "step {step}: timing mismatch ({},{}) vs ({start},{finish})",
                                p.start, p.finish
                            ));
                        }
                        if p.job != model.next_job {
                            return Err(format!("step {step}: job id {} != {}", p.job,
                                model.next_job));
                        }
                        if case.policy == Policy::ModelAffinity {
                            if let Some(mi) = m {
                                model.pins.entry(mi).or_insert(d);
                            }
                        }
                        model.next_job += 1;
                        model.accepted.insert(p.job);
                        model.tails[d] = finish;
                        model.queues[d].push_back((p.job, finish));
                    }
                    (e, g) => {
                        return Err(format!(
                            "step {step}: admission disagreement: want {e:?}, fleet {:?}",
                            g.map(|p| p.device)
                        ))
                    }
                }
            }
            Cmd::Complete => check_completion(&mut fleet, &mut model)?,
            Cmd::Drain => {
                while model.expected_completion().is_some() {
                    check_completion(&mut fleet, &mut model)?;
                }
                if fleet.next_completion().is_some() {
                    return Err(format!("step {step}: fleet had work after drain"));
                }
                if fleet.in_flight() != 0 {
                    return Err(format!("step {step}: in_flight != 0 after drain"));
                }
            }
            Cmd::Advance { dt_ms } => {
                let t = model.now + dt_ms as f64 / 1e3;
                fleet.advance_to(t);
                model.now = t;
            }
        }
        // global invariants after every command
        if (fleet.now() - model.now).abs() > 0.0 {
            return Err(format!("step {step}: clock skew {} vs {}", fleet.now(), model.now));
        }
        for (d, dev) in fleet.devices().iter().enumerate() {
            if dev.queue_len() > case.queue_bound {
                return Err(format!("step {step}: device {d} over its queue bound"));
            }
            if dev.queue_len() != model.queues[d].len() {
                return Err(format!(
                    "step {step}: device {d} queue {} vs model {}",
                    dev.queue_len(),
                    model.queues[d].len()
                ));
            }
        }
    }

    // epilogue: drain everything — every accepted job completes exactly once
    while model.expected_completion().is_some() {
        check_completion(&mut fleet, &mut model)?;
    }
    if fleet.in_flight() != 0 {
        return Err("undrained work at end".into());
    }
    if model.completed != model.accepted {
        return Err(format!(
            "accepted {} != completed {}",
            model.accepted.len(),
            model.completed.len()
        ));
    }
    let st = fleet.stats;
    if st.accepted != model.accepted.len() as u64 || st.completed != model.completed.len() as u64 {
        return Err(format!("stats disagree: {st:?}"));
    }
    if st.accepted + st.rejected != st.submitted {
        return Err(format!("admission accounting broken: {st:?}"));
    }
    Ok(())
}

#[test]
fn stateful_fleet_matches_reference_model() {
    check(&cfg(48), gen_case, |c| run_case(c), shrink_case);
}

// ---- differential batching properties ----

#[test]
fn batched_cpu_reference_bit_identical_to_single_runs() {
    // bit-identity, not allclose: the batched reference IS n independent
    // single-image convolutions
    check(
        &cfg(32),
        |rng| {
            let c = rng.range_usize(1, 6);
            let w = rng.range_usize(4, 12);
            let k = rng.range_usize(1, 3.min(w));
            let m = rng.range_usize(1, 6);
            let n = rng.range_usize(1, 6);
            (ConvProblem { c, wy: w, wx: w, m, k }, n, rng.next_u64())
        },
        |&(p, n, seed)| {
            let b = BatchedConv::new(p, n);
            let mut rng = Rng::new(seed);
            let images = rng.normal_vec(b.map_elems());
            let filters = rng.normal_vec(p.filter_elems());
            let batched = conv2d_batched_cpu(&b, &images, &filters);
            if batched.len() != n * p.out_elems() {
                return Err("wrong batched output size".into());
            }
            for i in 0..n {
                let single = conv2d_multi_cpu(
                    &p,
                    &images[i * p.map_elems()..(i + 1) * p.map_elems()],
                    &filters,
                );
                // f32 bit equality
                let same = batched[i * p.out_elems()..(i + 1) * p.out_elems()]
                    .iter()
                    .zip(&single)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    return Err(format!("image {i} of {} differs from single run", b.label()));
                }
            }
            Ok(())
        },
        |_| vec![],
    );
}

#[test]
fn batched_predicted_cycles_monotone_and_amortizing() {
    let g = gtx_1080ti();
    for p in templates() {
        let single = plans::batched_cycles(&BatchedConv::single(p), &g);
        let mut last = 0.0;
        for n in 1..=8usize {
            let c = plans::batched_cycles(&BatchedConv::new(p, n), &g);
            assert!(c > last, "{}: cycles not monotone at n={n}", p.label());
            assert!(
                c <= n as f64 * single * (1.0 + 1e-9),
                "{}: batch of {n} slower than {n} launches",
                p.label()
            );
            last = c;
        }
    }
    // and the same holds for real op jobs through the op path
    for op in op_templates() {
        let single = plans::batched_op_cycles(&BatchedConvOp::single(op), &g);
        let mut last = 0.0;
        for n in 1..=8usize {
            let c = plans::batched_op_cycles(&BatchedConvOp::new(op, n), &g);
            assert!(c > last, "{}: op cycles not monotone at n={n}", op.label());
            assert!(
                c <= n as f64 * single * (1.0 + 1e-9),
                "{}: op batch of {n} slower than {n} launches",
                op.label()
            );
            last = c;
        }
    }
}

#[test]
fn fleet_makespan_at_least_batch_over_devices_scaled_cost() {
    // n identical single-image jobs over D homogeneous devices cannot
    // drain faster than the n/D-scaled single-image cost
    let g = gtx_1080ti();
    let p = op_templates()[0];
    for d in [1usize, 2, 4, 8] {
        let mut fleet = Fleet::homogeneous(
            d,
            &g,
            FleetConfig { policy: Policy::LeastLoaded, queue_bound: 64 },
        );
        let single = fleet.predicted_service(&BatchedConvOp::single(p), 0);
        let n = 24;
        for _ in 0..n {
            assert!(fleet.submit(BatchedConvOp::single(p), None).is_some());
        }
        let makespan = fleet
            .drain()
            .iter()
            .map(|c| c.finish)
            .fold(0.0f64, f64::max);
        let floor = (n as f64 / d as f64) * single;
        assert!(
            makespan >= floor * (1.0 - 1e-9),
            "{d} devices: makespan {makespan} below the n/devices floor {floor}"
        );
        // and with perfect balance on identical jobs it IS the ceiling
        let ceiling = (n as f64 / d as f64).ceil() * single;
        assert!(makespan <= ceiling * (1.0 + 1e-9), "{d} devices: {makespan} > {ceiling}");
    }
}

#[test]
fn batched_jobs_beat_singles_end_to_end() {
    // serving n images as one batch drains faster than n single jobs —
    // the admission path's reason to coalesce
    let g = gtx_1080ti();
    let p = op_templates()[0];
    let cfg = FleetConfig { policy: Policy::LeastLoaded, queue_bound: 64 };
    let n = 8;
    let mut singles = Fleet::homogeneous(2, &g, cfg);
    for _ in 0..n {
        singles.submit(BatchedConvOp::single(p), None).unwrap();
    }
    let t_singles = singles.drain().iter().map(|c| c.finish).fold(0.0f64, f64::max);
    let mut batched = Fleet::homogeneous(2, &g, cfg);
    batched.submit(BatchedConvOp::new(p, n / 2), None).unwrap();
    batched.submit(BatchedConvOp::new(p, n / 2), None).unwrap();
    let t_batched = batched.drain().iter().map(|c| c.finish).fold(0.0f64, f64::max);
    assert!(
        t_batched < t_singles,
        "batched {t_batched} not faster than singles {t_singles}"
    );
}
