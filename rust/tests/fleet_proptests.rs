//! Stateful property tests for the fleet scheduler, modeled on
//! proptest-stateful's plan/check loop: random submit / complete /
//! drain / advance command sequences run against the real `Fleet` while
//! an in-test reference model replays every transition independently.
//!
//! Pinned invariants:
//!  * every accepted request completes exactly once (never lost, never
//!    duplicated), across completes, drains and interleaved submits;
//!  * no device ever exceeds its queue bound, and admission rejects
//!    exactly when every candidate is queue-full or pool-full;
//!  * no device's memory pool ever exceeds its byte cap — including
//!    while two or more different models are concurrently resident on
//!    one shard (the multi-tenant regime this PR adds);
//!  * least-loaded never picks a strictly worse device: the chosen
//!    shard's predicted completion is minimal among admissible shards;
//!  * round-robin visits devices cyclically (skipping full queues) and
//!    model-affinity stays pinned, spilling only under pressure;
//!  * placements and completions match the reference model exactly
//!    (same start/finish arithmetic, same event order, same clock,
//!    same pool occupancy / carve / reuse accounting).
//!
//! A second stateful harness drives the `DevicePool` itself through
//! alloc / free / double-free / execute-under-cap / trim transitions
//! against an independent reference allocator: slabs are exclusive (no
//! overlap by accounting), the cap is never exceeded, frees are
//! exactly-once, and fragmentation stays under the size-class bound
//! (`ARENA_ALIGN - 1` per live allocation).
//!
//! Plus the differential batching properties the batch-aware serving
//! path rests on: the batched CPU reference is bit-identical to `n`
//! independent single-image runs, and batched predicted cycles are
//! monotone in `n`, amortizing (<= n independent launches) and bounded
//! below by the n/devices-scaled single-image cost at the fleet level.
//!
//! Seed and case count are fixed (CI runs this file directly) so the
//! runtime stays bounded and failures replay deterministically.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

use pasconv::conv::{
    conv2d_batched_cpu, conv2d_multi_cpu, BatchedConv, BatchedConvOp, ConvOp, ConvProblem,
};
use pasconv::fleet::{size_class, DevicePool, Fleet, FleetConfig, PoolError, Policy};
use pasconv::gpusim::{gtx_1080ti, titan_x_maxwell, GpuSpec};
use pasconv::graph::{
    liveness, plan_pooled, topo_order, Graph, GraphBuilder, Shape, TensorLife, ARENA_ALIGN,
};
use pasconv::plans;
use pasconv::util::prop::{check, Config};
use pasconv::util::rng::Rng;

/// Fixed seed + case count: bounded runtime, deterministic replays.
fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0xF1EE7D }
}

/// Small problems (fast to tune once per process) that still cover both
/// kernels.
fn templates() -> Vec<ConvProblem> {
    vec![
        ConvProblem::multi(8, 14, 16, 3),
        ConvProblem::single(32, 16, 3),
        ConvProblem::multi(16, 7, 32, 3),
    ]
}

/// Fleet job templates: the dense problems plus real op-layer jobs
/// (a stride-2 downsampler and a depthwise 3x3) — the scheduler prices
/// all of them through the same per-shard op dispatcher.
fn op_templates() -> Vec<ConvOp> {
    let mut out: Vec<ConvOp> = templates().into_iter().map(ConvOp::dense).collect();
    out.push(ConvOp::strided(ConvProblem::multi(8, 28, 16, 3), 2, 1));
    out.push(ConvOp::depthwise(16, 14, 3, 1));
    out
}

const MODELS: [&str; 3] = ["alexnet", "resnet18", "vgg16"];

/// Largest footprint the generator can produce (biggest template at
/// n = 8) — capped cases size their pools in units of this so the cap
/// actually bites.
fn max_footprint() -> usize {
    op_templates().iter().map(|&op| BatchedConvOp::new(op, 8).footprint_bytes()).max().unwrap()
}

#[derive(Clone, Debug)]
enum Cmd {
    Submit { template: usize, n: usize, model: Option<usize> },
    Complete,
    Drain,
    Advance { dt_ms: u64 },
}

#[derive(Clone, Debug)]
struct Case {
    policy: Policy,
    devices: usize,
    hetero: bool,
    queue_bound: usize,
    /// 0 = uncapped (DRAM-sized pools, fits always), 1 = tight
    /// (2x the largest job), 2 = roomy (5x) — tight caps force memory
    /// rejections and evictions, roomy ones force multi-tenancy
    cap_class: usize,
    cmds: Vec<Cmd>,
}

fn capacity_for(c: &Case) -> Option<usize> {
    match c.cap_class {
        0 => None,
        1 => Some(2 * max_footprint()),
        _ => Some(5 * max_footprint()),
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    let policy = *rng.choose(&[
        Policy::RoundRobin,
        Policy::LeastLoaded,
        Policy::LeastLoadedBytes,
        Policy::ModelAffinity,
    ]);
    let devices = rng.range_usize(1, 4);
    let hetero = rng.range_usize(0, 1) == 1;
    let queue_bound = rng.range_usize(1, 4);
    let cap_class = rng.range_usize(0, 2);
    let n_cmds = rng.range_usize(10, 40);
    let cmds = (0..n_cmds)
        .map(|_| match rng.range_usize(0, 9) {
            0..=5 => Cmd::Submit {
                template: rng.range_usize(0, op_templates().len() - 1),
                n: [1, 2, 4, 8][rng.range_usize(0, 3)],
                model: match rng.range_usize(0, 3) {
                    0 => None,
                    i => Some(i - 1),
                },
            },
            6 | 7 => Cmd::Complete,
            8 => Cmd::Advance { dt_ms: rng.range_u64(1, 50) },
            _ => Cmd::Drain,
        })
        .collect();
    Case { policy, devices, hetero, queue_bound, cap_class, cmds }
}

/// Shrink a failing case by truncating the command tail.
fn shrink_case(c: &Case) -> Vec<Case> {
    let mut out = vec![];
    if c.cmds.len() > 1 {
        out.push(Case { cmds: c.cmds[..c.cmds.len() / 2].to_vec(), ..c.clone() });
        out.push(Case { cmds: c.cmds[..c.cmds.len() - 1].to_vec(), ..c.clone() });
    }
    out
}

fn specs_for(c: &Case) -> Vec<GpuSpec> {
    (0..c.devices)
        .map(|i| if c.hetero && i % 2 == 1 { titan_x_maxwell() } else { gtx_1080ti() })
        .collect()
}

/// Byte-level mirror of one shard's `DevicePool`.  Job footprints are
/// already `ARENA_ALIGN`-aligned, so class == bytes here; only counts
/// per class are tracked (which slab id a class reuses never changes
/// the byte accounting).
#[derive(Clone)]
struct RefPool {
    cap: usize,
    /// total carved slab bytes (parked + in use) — must never top `cap`
    carved: usize,
    in_use: usize,
    free: BTreeMap<usize, usize>, // class -> parked slab count
}

impl RefPool {
    fn new(cap: usize) -> RefPool {
        RefPool { cap, carved: 0, in_use: 0, free: BTreeMap::new() }
    }

    fn can_fit(&self, class: usize) -> bool {
        self.free.get(&class).copied().unwrap_or(0) > 0 || self.in_use + class <= self.cap
    }

    fn occupancy_after(&self, class: usize) -> f64 {
        (self.in_use + class) as f64 / self.cap as f64
    }

    /// Evict one parked slab, largest class first (mirrors
    /// `DevicePool::evict_one`).  False when nothing is parked.
    fn evict_largest(&mut self) -> bool {
        let Some((&big, _)) = self.free.iter().next_back() else {
            return false;
        };
        let n = self.free.get_mut(&big).unwrap();
        *n -= 1;
        if *n == 0 {
            self.free.remove(&big);
        }
        self.carved -= big;
        true
    }

    /// Mirror of `DevicePool::alloc` for an admission-checked class:
    /// exact-class reuse, else carve (evicting parked slabs until the
    /// carve fits — admission guaranteed it will).
    fn alloc(&mut self, class: usize) {
        if let Some(n) = self.free.get_mut(&class) {
            *n -= 1;
            if *n == 0 {
                self.free.remove(&class);
            }
        } else {
            while self.carved + class > self.cap && self.evict_largest() {}
            assert!(self.carved + class <= self.cap, "ref model admitted an unfittable job");
            self.carved += class;
        }
        self.in_use += class;
    }

    fn release(&mut self, class: usize) {
        self.in_use -= class;
        *self.free.entry(class).or_insert(0) += 1;
    }
}

/// One resident job in the reference model.
#[derive(Clone, Copy)]
struct RefJob {
    id: u64,
    finish: f64,
    /// pool footprint held from placement to completion
    class: usize,
    model: Option<usize>,
}

/// The reference model: an independent replay of the fleet's contract.
struct RefModel {
    now: f64,
    tails: Vec<f64>,
    queues: Vec<VecDeque<RefJob>>,
    pools: Vec<RefPool>,
    bound: usize,
    rr_cursor: usize,
    pins: HashMap<usize, usize>, // model idx -> device
    accepted: HashSet<u64>,
    completed: HashSet<u64>,
    next_job: u64,
    mem_rejected: u64,
}

impl RefModel {
    fn new(caps: Vec<usize>, bound: usize) -> RefModel {
        let devices = caps.len();
        RefModel {
            now: 0.0,
            tails: vec![0.0; devices],
            queues: vec![VecDeque::new(); devices],
            pools: caps.into_iter().map(RefPool::new).collect(),
            bound,
            rr_cursor: 0,
            pins: HashMap::new(),
            accepted: HashSet::new(),
            completed: HashSet::new(),
            next_job: 1,
            mem_rejected: 0,
        }
    }

    fn full(&self, d: usize) -> bool {
        self.queues[d].len() >= self.bound
    }

    /// Queue slot AND pool room — mirror of `PlacementCandidate::admissible`.
    fn admissible(&self, d: usize, class: usize) -> bool {
        !self.full(d) && self.pools[d].can_fit(class)
    }

    fn completion_if_placed(&self, d: usize, service: &[f64]) -> f64 {
        self.tails[d].max(self.now) + service[d]
    }

    fn least_loaded(&self, service: &[f64], class: usize) -> Option<usize> {
        (0..self.queues.len())
            .filter(|&d| self.admissible(d, class))
            .min_by(|&a, &b| {
                self.completion_if_placed(a, service)
                    .partial_cmp(&self.completion_if_placed(b, service))
                    .unwrap()
                    .then(a.cmp(&b))
            })
    }

    /// Completion weighted by pool pressure — mirror of
    /// `PlacementCandidate::weighted_completion`.
    fn least_loaded_bytes(&self, service: &[f64], class: usize) -> Option<usize> {
        (0..self.queues.len())
            .filter(|&d| self.admissible(d, class))
            .min_by(|&a, &b| {
                let wa = self.completion_if_placed(a, service)
                    * (1.0 + self.pools[a].occupancy_after(class));
                let wb = self.completion_if_placed(b, service)
                    * (1.0 + self.pools[b].occupancy_after(class));
                wa.partial_cmp(&wb).unwrap().then(a.cmp(&b))
            })
    }

    /// The device the policy must choose, mirroring the scheduler.
    /// Affinity pins are recorded by the caller on ACCEPTED placements
    /// only — a rejected first sight must not pin.
    fn expected_pick(
        &mut self,
        policy: Policy,
        model: Option<usize>,
        service: &[f64],
        class: usize,
    ) -> Option<usize> {
        match policy {
            Policy::RoundRobin => {
                let n = self.queues.len();
                let pick = (0..n)
                    .map(|i| (self.rr_cursor + i) % n)
                    .find(|&d| self.admissible(d, class));
                if let Some(d) = pick {
                    self.rr_cursor = (d + 1) % n;
                }
                pick
            }
            Policy::LeastLoaded => self.least_loaded(service, class),
            Policy::LeastLoadedBytes => self.least_loaded_bytes(service, class),
            Policy::ModelAffinity => match model.and_then(|m| self.pins.get(&m).copied()) {
                None => self.least_loaded(service, class),
                Some(pin) if self.admissible(pin, class) => Some(pin),
                Some(_) => self.least_loaded(service, class),
            },
        }
    }

    /// Earliest head-of-queue finish (tie -> lowest device).
    fn expected_completion(&self) -> Option<(usize, u64, f64)> {
        (0..self.queues.len())
            .filter_map(|d| self.queues[d].front().map(|j| (d, j.id, j.finish)))
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap().then(a.0.cmp(&b.0)))
    }
}

/// Run one generated case: real fleet vs reference model, invariant
/// checks after every command.
fn run_case(case: &Case) -> Result<(), String> {
    let specs = specs_for(case);
    let capacity = capacity_for(case);
    let mut fleet = Fleet::new(
        specs.clone(),
        FleetConfig {
            policy: case.policy,
            queue_bound: case.queue_bound,
            capacity_bytes: capacity,
        },
    );
    let caps: Vec<usize> =
        specs.iter().map(|s| capacity.unwrap_or(s.dram_bytes as usize)).collect();
    let mut model = RefModel::new(caps, case.queue_bound);
    let temps = op_templates();

    let check_completion = |fleet: &mut Fleet, model: &mut RefModel| -> Result<(), String> {
        let expect = model.expected_completion();
        let got = fleet.next_completion();
        match (expect, got) {
            (None, None) => Ok(()),
            (Some((d, id, f)), Some(c)) => {
                if c.device != d || c.job != id || (c.finish - f).abs() > 0.0 {
                    return Err(format!(
                        "completion mismatch: got job {} dev {} finish {}, want {id}/{d}/{f}",
                        c.job, c.device, c.finish
                    ));
                }
                if !model.completed.insert(id) {
                    return Err(format!("job {id} completed twice"));
                }
                if !model.accepted.contains(&id) {
                    return Err(format!("job {id} completed but never accepted"));
                }
                let j = model.queues[d].pop_front().expect("head exists");
                model.pools[d].release(j.class);
                model.now = model.now.max(f);
                Ok(())
            }
            (e, g) => Err(format!("completion disagreement: want {e:?}, fleet {:?}",
                g.map(|c| (c.device, c.job, c.finish)))),
        }
    };

    for (step, cmd) in case.cmds.iter().enumerate() {
        match *cmd {
            Cmd::Submit { template, n, model: m } => {
                let conv = BatchedConvOp::new(temps[template], n);
                let class = conv.footprint_bytes();
                let service: Vec<f64> =
                    (0..case.devices).map(|d| fleet.predicted_service(&conv, d)).collect();
                let tag = m.map(|i| MODELS[i]);
                let expect = model.expected_pick(case.policy, m, &service, class);
                let got = fleet.submit(conv, tag);
                match (expect, got) {
                    (None, None) => {
                        if (0..case.devices).any(|d| model.admissible(d, class)) {
                            return Err(format!("step {step}: rejected with an admissible shard"));
                        }
                        if (0..case.devices).any(|d| !model.full(d)) {
                            // a queue slot existed: this rejection was
                            // memory's fault and must be counted as such
                            model.mem_rejected += 1;
                        }
                    }
                    (Some(d), Some(p)) => {
                        if p.device != d {
                            return Err(format!(
                                "step {step}: placed on {} but policy {:?} demands {d}",
                                p.device, case.policy
                            ));
                        }
                        // least-loaded minimality: no admissible shard
                        // was strictly better than the chosen one
                        if case.policy == Policy::LeastLoaded {
                            let chosen = model.completion_if_placed(d, &service);
                            for e in 0..case.devices {
                                if model.admissible(e, class)
                                    && model.completion_if_placed(e, &service) < chosen - 1e-12
                                {
                                    return Err(format!(
                                        "step {step}: least-loaded picked {d} over busier-free {e}"
                                    ));
                                }
                            }
                        }
                        let start = model.tails[d].max(model.now);
                        let finish = start + service[d];
                        if (p.start - start).abs() > 0.0 || (p.finish - finish).abs() > 0.0 {
                            return Err(format!(
                                "step {step}: timing mismatch ({},{}) vs ({start},{finish})",
                                p.start, p.finish
                            ));
                        }
                        if p.job != model.next_job {
                            return Err(format!("step {step}: job id {} != {}", p.job,
                                model.next_job));
                        }
                        if case.policy == Policy::ModelAffinity {
                            if let Some(mi) = m {
                                model.pins.entry(mi).or_insert(d);
                            }
                        }
                        model.next_job += 1;
                        model.accepted.insert(p.job);
                        model.tails[d] = finish;
                        model.pools[d].alloc(class);
                        model.queues[d].push_back(RefJob { id: p.job, finish, class, model: m });
                        // the acceptance criterion this PR pins: with two
                        // or more DIFFERENT models resident on one shard,
                        // the shard's pool still respects its byte cap
                        let tags: HashSet<usize> =
                            model.queues[d].iter().filter_map(|j| j.model).collect();
                        if tags.len() >= 2 {
                            let pool = fleet.devices()[d].pool();
                            if pool.in_use_slab_bytes() > pool.capacity() {
                                return Err(format!(
                                    "step {step}: {} models resident on shard {d} and its pool \
                                     burst the cap ({} > {})",
                                    tags.len(),
                                    pool.in_use_slab_bytes(),
                                    pool.capacity()
                                ));
                            }
                        }
                    }
                    (e, g) => {
                        return Err(format!(
                            "step {step}: admission disagreement: want {e:?}, fleet {:?}",
                            g.map(|p| p.device)
                        ))
                    }
                }
            }
            Cmd::Complete => check_completion(&mut fleet, &mut model)?,
            Cmd::Drain => {
                while model.expected_completion().is_some() {
                    check_completion(&mut fleet, &mut model)?;
                }
                if fleet.next_completion().is_some() {
                    return Err(format!("step {step}: fleet had work after drain"));
                }
                if fleet.in_flight() != 0 {
                    return Err(format!("step {step}: in_flight != 0 after drain"));
                }
            }
            Cmd::Advance { dt_ms } => {
                let t = model.now + dt_ms as f64 / 1e3;
                fleet.advance_to(t);
                model.now = t;
            }
        }
        // global invariants after every command
        if (fleet.now() - model.now).abs() > 0.0 {
            return Err(format!("step {step}: clock skew {} vs {}", fleet.now(), model.now));
        }
        for (d, dev) in fleet.devices().iter().enumerate() {
            if dev.queue_len() > case.queue_bound {
                return Err(format!("step {step}: device {d} over its queue bound"));
            }
            if dev.queue_len() != model.queues[d].len() {
                return Err(format!(
                    "step {step}: device {d} queue {} vs model {}",
                    dev.queue_len(),
                    model.queues[d].len()
                ));
            }
            let pool = dev.pool();
            if pool.slab_bytes() > pool.capacity() {
                return Err(format!(
                    "step {step}: device {d} pool carved past its cap ({} > {})",
                    pool.slab_bytes(),
                    pool.capacity()
                ));
            }
            if pool.in_use_slab_bytes() != model.pools[d].in_use {
                return Err(format!(
                    "step {step}: device {d} pool in-use {} vs model {}",
                    pool.in_use_slab_bytes(),
                    model.pools[d].in_use
                ));
            }
            if pool.slab_bytes() != model.pools[d].carved {
                return Err(format!(
                    "step {step}: device {d} pool carved {} vs model {}",
                    pool.slab_bytes(),
                    model.pools[d].carved
                ));
            }
            if pool.live_allocs() != model.queues[d].len() {
                return Err(format!(
                    "step {step}: device {d} holds {} pool allocations for {} resident jobs",
                    pool.live_allocs(),
                    model.queues[d].len()
                ));
            }
        }
    }

    // epilogue: drain everything — every accepted job completes exactly once
    while model.expected_completion().is_some() {
        check_completion(&mut fleet, &mut model)?;
    }
    if fleet.in_flight() != 0 {
        return Err("undrained work at end".into());
    }
    if model.completed != model.accepted {
        return Err(format!(
            "accepted {} != completed {}",
            model.accepted.len(),
            model.completed.len()
        ));
    }
    for (d, dev) in fleet.devices().iter().enumerate() {
        if dev.pool().in_use_slab_bytes() != 0 {
            return Err(format!("device {d} pool still holds bytes after the drain"));
        }
    }
    let st = fleet.stats;
    if st.accepted != model.accepted.len() as u64 || st.completed != model.completed.len() as u64 {
        return Err(format!("stats disagree: {st:?}"));
    }
    if st.accepted + st.rejected != st.submitted {
        return Err(format!("admission accounting broken: {st:?}"));
    }
    if st.mem_rejected != model.mem_rejected {
        return Err(format!(
            "memory rejections {} vs model {}",
            st.mem_rejected, model.mem_rejected
        ));
    }
    if st.mem_rejected > st.rejected {
        return Err(format!("mem_rejected outnumbers rejected: {st:?}"));
    }
    Ok(())
}

#[test]
fn stateful_fleet_matches_reference_model() {
    check(&cfg(48), gen_case, |c| run_case(c), shrink_case);
}

// ---- differential batching properties ----

#[test]
fn batched_cpu_reference_bit_identical_to_single_runs() {
    // bit-identity, not allclose: the batched reference IS n independent
    // single-image convolutions
    check(
        &cfg(32),
        |rng| {
            let c = rng.range_usize(1, 6);
            let w = rng.range_usize(4, 12);
            let k = rng.range_usize(1, 3.min(w));
            let m = rng.range_usize(1, 6);
            let n = rng.range_usize(1, 6);
            (ConvProblem { c, wy: w, wx: w, m, k }, n, rng.next_u64())
        },
        |&(p, n, seed)| {
            let b = BatchedConv::new(p, n);
            let mut rng = Rng::new(seed);
            let images = rng.normal_vec(b.map_elems());
            let filters = rng.normal_vec(p.filter_elems());
            let batched = conv2d_batched_cpu(&b, &images, &filters);
            if batched.len() != n * p.out_elems() {
                return Err("wrong batched output size".into());
            }
            for i in 0..n {
                let single = conv2d_multi_cpu(
                    &p,
                    &images[i * p.map_elems()..(i + 1) * p.map_elems()],
                    &filters,
                );
                // f32 bit equality
                let same = batched[i * p.out_elems()..(i + 1) * p.out_elems()]
                    .iter()
                    .zip(&single)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    return Err(format!("image {i} of {} differs from single run", b.label()));
                }
            }
            Ok(())
        },
        |_| vec![],
    );
}

#[test]
fn batched_predicted_cycles_monotone_and_amortizing() {
    let g = gtx_1080ti();
    for p in templates() {
        let single = plans::batched_cycles(&BatchedConv::single(p), &g);
        let mut last = 0.0;
        for n in 1..=8usize {
            let c = plans::batched_cycles(&BatchedConv::new(p, n), &g);
            assert!(c > last, "{}: cycles not monotone at n={n}", p.label());
            assert!(
                c <= n as f64 * single * (1.0 + 1e-9),
                "{}: batch of {n} slower than {n} launches",
                p.label()
            );
            last = c;
        }
    }
    // and the same holds for real op jobs through the op path
    for op in op_templates() {
        let single = plans::batched_op_cycles(&BatchedConvOp::single(op), &g);
        let mut last = 0.0;
        for n in 1..=8usize {
            let c = plans::batched_op_cycles(&BatchedConvOp::new(op, n), &g);
            assert!(c > last, "{}: op cycles not monotone at n={n}", op.label());
            assert!(
                c <= n as f64 * single * (1.0 + 1e-9),
                "{}: op batch of {n} slower than {n} launches",
                op.label()
            );
            last = c;
        }
    }
}

#[test]
fn fleet_makespan_at_least_batch_over_devices_scaled_cost() {
    // n identical single-image jobs over D homogeneous devices cannot
    // drain faster than the n/D-scaled single-image cost
    let g = gtx_1080ti();
    let p = op_templates()[0];
    for d in [1usize, 2, 4, 8] {
        let mut fleet = Fleet::homogeneous(
            d,
            &g,
            FleetConfig { policy: Policy::LeastLoaded, queue_bound: 64, capacity_bytes: None },
        );
        let single = fleet.predicted_service(&BatchedConvOp::single(p), 0);
        let n = 24;
        for _ in 0..n {
            assert!(fleet.submit(BatchedConvOp::single(p), None).is_some());
        }
        let makespan = fleet
            .drain()
            .iter()
            .map(|c| c.finish)
            .fold(0.0f64, f64::max);
        let floor = (n as f64 / d as f64) * single;
        assert!(
            makespan >= floor * (1.0 - 1e-9),
            "{d} devices: makespan {makespan} below the n/devices floor {floor}"
        );
        // and with perfect balance on identical jobs it IS the ceiling
        let ceiling = (n as f64 / d as f64).ceil() * single;
        assert!(makespan <= ceiling * (1.0 + 1e-9), "{d} devices: {makespan} > {ceiling}");
    }
}

#[test]
fn batched_jobs_beat_singles_end_to_end() {
    // serving n images as one batch drains faster than n single jobs —
    // the admission path's reason to coalesce
    let g = gtx_1080ti();
    let p = op_templates()[0];
    let cfg = FleetConfig { policy: Policy::LeastLoaded, queue_bound: 64, capacity_bytes: None };
    let n = 8;
    let mut singles = Fleet::homogeneous(2, &g, cfg);
    for _ in 0..n {
        singles.submit(BatchedConvOp::single(p), None).unwrap();
    }
    let t_singles = singles.drain().iter().map(|c| c.finish).fold(0.0f64, f64::max);
    let mut batched = Fleet::homogeneous(2, &g, cfg);
    batched.submit(BatchedConvOp::new(p, n / 2), None).unwrap();
    batched.submit(BatchedConvOp::new(p, n / 2), None).unwrap();
    let t_batched = batched.drain().iter().map(|c| c.finish).fold(0.0f64, f64::max);
    assert!(
        t_batched < t_singles,
        "batched {t_batched} not faster than singles {t_singles}"
    );
}

// ---- stateful pool-transition harness ----
//
// Drives a `DevicePool` directly (the fleet harness above only sees it
// through admission) with random alloc / free / double-free /
// execute-under-cap / trim sequences, replaying every transition on an
// independent reference allocator that tracks classes as counted
// multisets.  "No overlap" is exclusive slab ownership: one live
// allocation per slab, so the byte accounting (carved = parked +
// in-use, in-use = sum of live classes) must reconcile exactly.

/// Independent size-class arithmetic (must agree with `size_class`).
fn class_of(bytes: usize) -> usize {
    (bytes.max(1) + ARENA_ALIGN - 1) / ARENA_ALIGN * ARENA_ALIGN
}

/// Small graphs for execute-under-cap: tensors are 6.25 KiB classes, so
/// pools in the tens of KiB hit the success, eviction AND
/// exhaustion-rollback paths.
fn pool_graph(which: usize) -> Graph {
    let p = ConvProblem::multi(8, 14, 8, 3);
    let mut b = GraphBuilder::new(if which % 2 == 0 { "chain" } else { "diamond" });
    let x = b.input("in", Shape::new(8, 14, 14));
    if which % 2 == 0 {
        let mut t = x;
        for i in 0..4 {
            t = b.conv_same(&format!("c{i}"), t, p).unwrap();
        }
    } else {
        let l = b.conv_same("l", x, p).unwrap();
        let r = b.conv_same("r", x, p).unwrap();
        b.add_skip("join", l, r).unwrap();
    }
    b.finish().unwrap()
}

/// The reference allocator: counted class multisets + full stat mirror.
struct RefAlloc {
    cap: usize,
    carved: usize,
    in_use_class: usize,
    in_use_req: usize,
    free: BTreeMap<usize, usize>, // class -> parked count
    live: HashMap<u64, (usize, usize)>, // real alloc id -> (class, requested)
    allocs: u64,
    frees: u64,
    reuse: u64,
    evictions: u64,
    failed: u64,
    peak_class: usize,
    peak_req: usize,
}

impl RefAlloc {
    fn new(cap: usize) -> RefAlloc {
        RefAlloc {
            cap,
            carved: 0,
            in_use_class: 0,
            in_use_req: 0,
            free: BTreeMap::new(),
            live: HashMap::new(),
            allocs: 0,
            frees: 0,
            reuse: 0,
            evictions: 0,
            failed: 0,
            peak_class: 0,
            peak_req: 0,
        }
    }

    fn can_fit(&self, bytes: usize) -> bool {
        let class = class_of(bytes);
        self.free.get(&class).copied().unwrap_or(0) > 0 || self.in_use_class + class <= self.cap
    }

    fn evict_largest(&mut self) -> bool {
        let Some((&big, _)) = self.free.iter().next_back() else {
            return false;
        };
        let n = self.free.get_mut(&big).unwrap();
        *n -= 1;
        if *n == 0 {
            self.free.remove(&big);
        }
        self.carved -= big;
        self.evictions += 1;
        true
    }

    /// The transition `DevicePool::alloc` must make — including the
    /// side effects of a FAILED attempt (parked slabs evicted trying to
    /// make room, failed counter bumped).  True on success.
    fn try_alloc(&mut self, bytes: usize) -> bool {
        let class = class_of(bytes);
        if let Some(n) = self.free.get_mut(&class) {
            *n -= 1;
            if *n == 0 {
                self.free.remove(&class);
            }
            self.reuse += 1;
        } else {
            while self.carved + class > self.cap && self.evict_largest() {}
            if self.carved + class > self.cap {
                self.failed += 1;
                return false;
            }
            self.carved += class;
        }
        self.in_use_class += class;
        self.in_use_req += bytes;
        self.allocs += 1;
        self.peak_class = self.peak_class.max(self.in_use_class);
        self.peak_req = self.peak_req.max(self.in_use_req);
        true
    }

    fn free_anon(&mut self, class: usize, req: usize) {
        self.in_use_class -= class;
        self.in_use_req -= req;
        *self.free.entry(class).or_insert(0) += 1;
        self.frees += 1;
    }

    fn free_id(&mut self, id: u64) -> Result<(), String> {
        let (class, req) = self.live.remove(&id).ok_or(format!("ref lost alloc {id}"))?;
        self.free_anon(class, req);
        Ok(())
    }

    fn trim(&mut self) -> usize {
        let before = self.carved;
        while self.evict_largest() {}
        before - self.carved
    }

    /// Replay `plan_pooled`'s alloc/free trace: alloc at def step, free
    /// right after last use.  Some(peak live bytes) on success; None
    /// when the pool must exhaust (own allocations rolled back, any
    /// evictions along the way kept — they were parked).
    fn replay_execution(&mut self, lives: &[TensorLife], batch: usize) -> Option<usize> {
        let mut held: HashMap<usize, (usize, usize)> = HashMap::new();
        let (mut live_now, mut peak) = (0usize, 0usize);
        for step in 0..lives.len() {
            let bytes = lives[step].bytes * batch;
            if !self.try_alloc(bytes) {
                for (_, (class, req)) in held.drain() {
                    self.free_anon(class, req);
                }
                return None;
            }
            held.insert(step, (class_of(bytes), bytes));
            live_now += bytes;
            peak = peak.max(live_now);
            for (j, l) in lives.iter().enumerate().take(step + 1) {
                if l.last_use_step == step {
                    if let Some((class, req)) = held.remove(&j) {
                        self.free_anon(class, req);
                        live_now -= l.bytes * batch;
                    }
                }
            }
        }
        assert!(held.is_empty(), "ref replay leaked a tensor");
        Some(peak)
    }

    /// Reconcile every observable of the real pool with the reference.
    fn check(&self, pool: &DevicePool) -> Result<(), String> {
        if pool.slab_bytes() > pool.capacity() {
            return Err(format!(
                "cap exceeded: carved {} of {}",
                pool.slab_bytes(),
                pool.capacity()
            ));
        }
        let pairs = [
            ("carved", pool.slab_bytes(), self.carved),
            ("in-use", pool.in_use_slab_bytes(), self.in_use_class),
            ("requested", pool.in_use_requested_bytes(), self.in_use_req),
            ("parked", pool.free_slab_bytes(), self.carved - self.in_use_class),
            ("live", pool.live_allocs(), self.live.len()),
            ("frag", pool.fragmentation_bytes(), self.in_use_class - self.in_use_req),
        ];
        for (what, got, want) in pairs {
            if got != want {
                return Err(format!("{what}: pool {got} vs ref {want}"));
            }
        }
        if pool.fragmentation_bytes() > self.live.len() * (ARENA_ALIGN - 1) {
            return Err(format!(
                "fragmentation {} above the size-class bound for {} live allocs",
                pool.fragmentation_bytes(),
                self.live.len()
            ));
        }
        let st = [
            ("allocs", pool.stats.allocs, self.allocs),
            ("frees", pool.stats.frees, self.frees),
            ("reuse", pool.stats.reuse_hits, self.reuse),
            ("evictions", pool.stats.evictions, self.evictions),
            ("failed", pool.stats.failed_allocs, self.failed),
            ("peak", pool.stats.peak_in_use_slab as u64, self.peak_class as u64),
            ("peak-req", pool.stats.peak_in_use_requested as u64, self.peak_req as u64),
        ];
        for (what, got, want) in st {
            if got != want {
                return Err(format!("stat {what}: pool {got} vs ref {want}"));
            }
        }
        for probe in [1usize, 200, 6_272, 12_544, 25_088, 64 * 1024] {
            if pool.can_fit(probe) != self.can_fit(probe) {
                return Err(format!("can_fit({probe}) disagrees"));
            }
        }
        Ok(())
    }
}

#[derive(Clone, Debug)]
enum PoolCmd {
    Alloc { bytes: usize },
    FreeLive { idx: usize },
    /// free an id that never existed — must error, pool untouched
    FreeForeign,
    /// free the most recently freed id again — exactly-once semantics
    FreeAgain,
    Execute { which: usize, batch: usize },
    Trim,
}

#[derive(Clone, Debug)]
struct PoolCase {
    capacity: usize,
    cmds: Vec<PoolCmd>,
}

fn gen_pool_case(rng: &mut Rng) -> PoolCase {
    // 8..48 KiB around 6.25-12.8 KiB tensor classes: plenty of cases on
    // both sides of fitting
    let capacity = rng.range_usize(8, 48) * 1024;
    let n_cmds = rng.range_usize(15, 50);
    let cmds = (0..n_cmds)
        .map(|_| match rng.range_usize(0, 11) {
            0..=3 => PoolCmd::Alloc { bytes: rng.range_usize(1, 20) * 800 },
            4..=6 => PoolCmd::FreeLive { idx: rng.range_usize(0, 7) },
            7 | 8 => PoolCmd::Execute {
                which: rng.range_usize(0, 1),
                batch: rng.range_usize(1, 2),
            },
            9 => PoolCmd::FreeForeign,
            10 => PoolCmd::FreeAgain,
            _ => PoolCmd::Trim,
        })
        .collect();
    PoolCase { capacity, cmds }
}

fn shrink_pool_case(c: &PoolCase) -> Vec<PoolCase> {
    let mut out = vec![];
    if c.cmds.len() > 1 {
        out.push(PoolCase { cmds: c.cmds[..c.cmds.len() / 2].to_vec(), ..c.clone() });
        out.push(PoolCase { cmds: c.cmds[..c.cmds.len() - 1].to_vec(), ..c.clone() });
    }
    out
}

fn run_pool_case(case: &PoolCase) -> Result<(), String> {
    let mut pool = DevicePool::new(case.capacity);
    let mut r = RefAlloc::new(case.capacity);
    let mut live_ids: Vec<u64> = vec![];
    let mut last_freed: Option<u64> = None;
    let graphs = [pool_graph(0), pool_graph(1)];
    for (step, cmd) in case.cmds.iter().enumerate() {
        match *cmd {
            PoolCmd::Alloc { bytes } => {
                if class_of(bytes) != size_class(bytes) {
                    return Err(format!("step {step}: size_class({bytes}) disagrees"));
                }
                let fit = r.can_fit(bytes);
                if pool.can_fit(bytes) != fit {
                    return Err(format!("step {step}: can_fit({bytes}) disagrees pre-alloc"));
                }
                match pool.alloc(bytes) {
                    Ok(id) => {
                        if !r.try_alloc(bytes) {
                            return Err(format!(
                                "step {step}: pool admitted {bytes} B the ref calls exhausted"
                            ));
                        }
                        if !fit {
                            return Err(format!("step {step}: can_fit said no, alloc said yes"));
                        }
                        r.live.insert(id, (class_of(bytes), bytes));
                        live_ids.push(id);
                    }
                    Err(PoolError::Exhausted { .. }) => {
                        if fit {
                            return Err(format!("step {step}: can_fit said yes, alloc said no"));
                        }
                        if r.try_alloc(bytes) {
                            return Err(format!(
                                "step {step}: pool failed {bytes} B the ref would serve"
                            ));
                        }
                    }
                    Err(e) => return Err(format!("step {step}: unexpected error {e}")),
                }
            }
            PoolCmd::FreeLive { idx } => {
                if !live_ids.is_empty() {
                    let id = live_ids.remove(idx % live_ids.len());
                    pool.free(id).map_err(|e| format!("step {step}: live free failed: {e}"))?;
                    r.free_id(id).map_err(|e| format!("step {step}: {e}"))?;
                    last_freed = Some(id);
                }
            }
            PoolCmd::FreeForeign => match pool.free(u64::MAX) {
                Err(PoolError::UnknownAlloc(_)) => {}
                other => {
                    return Err(format!("step {step}: foreign free returned {other:?}"))
                }
            },
            PoolCmd::FreeAgain => {
                if let Some(id) = last_freed {
                    match pool.free(id) {
                        Err(PoolError::UnknownAlloc(got)) if got == id => {}
                        other => {
                            return Err(format!("step {step}: double free returned {other:?}"))
                        }
                    }
                }
            }
            PoolCmd::Execute { which, batch } => {
                let g = &graphs[which % 2];
                let order = topo_order(g);
                let expect = r.replay_execution(&liveness(g, &order), batch);
                match (plan_pooled(g, &order, batch, &mut pool), expect) {
                    (Ok(plan), Some(peak)) => {
                        if plan.peak_bytes != peak {
                            return Err(format!(
                                "step {step}: execution peak {} vs ref {peak}",
                                plan.peak_bytes
                            ));
                        }
                        if plan.allocs != g.len() as u64 {
                            return Err(format!("step {step}: {} allocs for {} nodes",
                                plan.allocs, g.len()));
                        }
                    }
                    (Err(PoolError::Exhausted { .. }), None) => {}
                    (got, want) => {
                        return Err(format!(
                            "step {step}: execution disagreement: pool {:?}, ref fits={}",
                            got.map(|p| p.peak_bytes),
                            want.is_some()
                        ))
                    }
                }
            }
            PoolCmd::Trim => {
                let freed = pool.evict_free();
                let want = r.trim();
                if freed != want {
                    return Err(format!("step {step}: trim reclaimed {freed} vs ref {want}"));
                }
            }
        }
        r.check(&pool).map_err(|e| format!("step {step}: {e}"))?;
    }
    // epilogue: free every live allocation, then the pool must reconcile
    // to an all-parked state with zero fragmentation
    for id in live_ids.drain(..) {
        pool.free(id).map_err(|e| format!("epilogue free: {e}"))?;
        r.free_id(id)?;
    }
    r.check(&pool)?;
    if pool.in_use_slab_bytes() != 0 || pool.fragmentation_bytes() != 0 {
        return Err("pool not empty after freeing everything".into());
    }
    Ok(())
}

#[test]
fn stateful_pool_matches_reference_allocator() {
    check(&cfg(64), gen_pool_case, |c| run_pool_case(c), shrink_pool_case);
}
