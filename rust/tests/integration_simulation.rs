//! Integration over the simulation stack: the paper's headline claims,
//! checked end-to-end (analytic model -> plans -> baselines -> gpusim).
//! These are the pass criteria of DESIGN.md §5 — shape, not absolutes.
//!
//! The paper-claim tests pin `paper_plan_for` (the verbatim §3 picks):
//! they document the *reproduction*, which must not drift as the tuner
//! improves the serving path.  The tuner's own gate — tuned plans never
//! lose to the paper's, and beat them somewhere — is the last test.

use pasconv::baselines::{cudnn_proxy, dac17, tan128};
use pasconv::conv::suites::{fig4_suite, fig5_suite, FIG4_POINTS, FIG5_POINTS};
use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, simulate, speedup, titan_x_maxwell};
use pasconv::plans::{paper_plan_for, plan_for};
use pasconv::util::stats::geomean;

/// Fig. 4 claim: "Our method is faster than Cudnn v7.1 in all tested
/// cases. The performance gain is 1.5X to 5.6X, and its average is 2.6X."
#[test]
fn fig4_ours_beats_cudnn_everywhere() {
    let g = gtx_1080ti();
    let mut speedups = vec![];
    for p in fig4_suite() {
        let s = speedup(&g, &paper_plan_for(&p, &g), &cudnn_proxy::plan(&p, &g));
        assert!(s > 1.0, "{}: {s:.2}x — cudnn proxy wins", p.label());
        speedups.push(s);
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    assert!(avg > 1.5 && avg < 4.0, "average {avg:.2} far from the paper's 2.6x");
}

/// Fig. 5 claim: "our method is faster than Cudnn in all tested cases,
/// and the throughput has been increased by 1.05X to 2X, with an average
/// increase of 1.39X."
#[test]
fn fig5_ours_beats_cudnn_everywhere() {
    let g = gtx_1080ti();
    let mut speedups = vec![];
    for p in fig5_suite() {
        let s = speedup(&g, &paper_plan_for(&p, &g), &cudnn_proxy::plan(&p, &g));
        assert!(s > 1.0, "{}: {s:.2}x — cudnn proxy wins", p.label());
        speedups.push(s);
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    assert!(avg > 1.1 && avg < 2.2, "average {avg:.2} far from the paper's 1.39x");
    // multi-channel gains are smaller than single-channel gains (paper:
    // 2.6x vs 1.39x)
    let g4: Vec<f64> = fig4_suite()
        .iter()
        .map(|p| speedup(&g, &paper_plan_for(p, &g), &cudnn_proxy::plan(p, &g)))
        .collect();
    assert!(geomean(&g4) > geomean(&speedups), "single-channel advantage missing");
}

/// §1 claim: the gains against tile-based baselines concentrate on small
/// maps — "[1] cannot handle the modern CNN models efficiently" (maps
/// < 32).
#[test]
fn small_map_gains_exceed_large_map_gains() {
    let g = gtx_1080ti();
    let small = ConvProblem::multi(256, 14, 256, 3);
    let large = ConvProblem::multi(64, 224, 64, 3);
    let s_small = speedup(&g, &paper_plan_for(&small, &g), &cudnn_proxy::plan(&small, &g));
    let s_large = speedup(&g, &paper_plan_for(&large, &g), &cudnn_proxy::plan(&large, &g));
    assert!(
        s_small > s_large,
        "small-map gain {s_small:.2} <= large-map gain {s_large:.2}"
    );
}

/// §4 claim vs [1]: "when K=3, our performance is 4X faster than [1]"
/// (after normalizing their 2.4x-slower GPU; here both run on the same
/// simulated 1080Ti, so the expected margin is ~4/2.4 ≈ 1.7x on the
/// small-map suite where [1] degrades, and >= 1x everywhere).
#[test]
fn dac17_comparison_at_k3() {
    let g = gtx_1080ti();
    let mut speedups = vec![];
    for &(w, c) in &FIG5_POINTS {
        let p = ConvProblem::multi(c, w, c, 3);
        let s = speedup(&g, &paper_plan_for(&p, &g), &dac17::plan(&p, &g));
        assert!(s > 0.95, "{}: dac17 wins ({s:.2})", p.label());
        speedups.push(s);
    }
    let avg = geomean(&speedups);
    assert!(avg > 1.3, "geomean vs dac17 = {avg:.2}, paper implies ~1.7");
    // and the degradation is concentrated below 32 px (their documented flaw)
    let small = ConvProblem::multi(256, 14, 256, 3);
    let s_small = speedup(&g, &paper_plan_for(&small, &g), &dac17::plan(&small, &g));
    assert!(s_small > 2.0, "small-map margin vs [1] only {s_small:.2}x");
}

/// §3.2 trade-off vs [16]: ahead overall and clearly ahead where DRAM
/// bandwidth binds (small M' multiplies [16]'s map traffic).  Model
/// finding recorded in EXPERIMENTS.md: on a few small-map compute-bound
/// shapes S=128's chunkier rounds win locally — the paper's S ∈ {32,64}
/// restriction is not uniformly optimal under the latency-exposure
/// model, but the aggregate claim holds.
#[test]
fn tan128_never_faster_overall() {
    let g = gtx_1080ti();
    let mut speedups = vec![];
    for p in fig5_suite() {
        let s = speedup(&g, &paper_plan_for(&p, &g), &tan128::plan(&p, &g));
        assert!(s > 0.6, "{}: tan128 wins by >40% ({s:.2})", p.label());
        speedups.push(s);
    }
    assert!(geomean(&speedups) >= 1.0, "geomean {:.3}", geomean(&speedups));
    // where bandwidth binds, the win is decisive
    let p = ConvProblem::multi(128, 112, 128, 1);
    let s = speedup(&g, &paper_plan_for(&p, &g), &tan128::plan(&p, &g));
    assert!(s > 1.3, "bandwidth-bound case only {s:.2}x");
}

/// §4 Maxwell claim: "our performance is faster than Cudnn on the same
/// GPU [Titan X] by 1.3X to 3.7X in the single-channel ... and 1.08X to
/// 1.8X in the multi-channel" — the approach transfers across
/// architectures.
#[test]
fn maxwell_portability() {
    let t = titan_x_maxwell();
    for p in fig4_suite() {
        let s = speedup(&t, &paper_plan_for(&p, &t), &cudnn_proxy::plan(&p, &t));
        assert!(s > 1.0, "single-channel {} on Titan X: {s:.2}", p.label());
    }
    let mut multi = vec![];
    for p in fig5_suite() {
        let s = speedup(&t, &paper_plan_for(&p, &t), &cudnn_proxy::plan(&p, &t));
        assert!(s > 0.95, "multi-channel {} on Titan X: {s:.2}", p.label());
        multi.push(s);
    }
    assert!(geomean(&multi) > 1.05);
}

/// Fig. 4 regime check: the P/Q procedure switches to the V_s volume
/// strategy exactly where the paper says prefetching starves (small
/// single-channel maps), and to prefetch where work is plentiful.
#[test]
fn strategy_switches_with_problem_size() {
    use pasconv::analytic::choose_single;
    let g = gtx_1080ti();
    let starved = choose_single(&ConvProblem::single(28, 32, 1), &g);
    assert!(!starved.uses_prefetch, "28x28/M=32/K=1 should fall back to V_s");
    let rich = choose_single(&ConvProblem::single(512, 512, 5), &g);
    assert!(rich.uses_prefetch, "512x512/M=512/K=5 should prefetch");
}

/// Sanity on the figure suites themselves: reported times grow with work.
#[test]
fn simulated_time_grows_with_map_size_at_fixed_m() {
    let g = gtx_1080ti();
    let mut last = 0.0;
    for w in [64, 128, 256, 512, 1024] {
        let p = ConvProblem::single(w, 32, 3);
        let t = simulate(&g, &paper_plan_for(&p, &g)).seconds;
        assert!(t > last, "W={w}: {t} <= {last}");
        last = t;
    }
}

/// The Fig. 4 suite spans both strategies — otherwise the figure would
/// not exercise the paper's contribution.
#[test]
fn fig4_contains_both_strategies() {
    use pasconv::analytic::choose_single;
    let g = gtx_1080ti();
    let choices: Vec<bool> =
        fig4_suite().iter().map(|p| choose_single(p, &g).uses_prefetch).collect();
    assert!(choices.iter().any(|&x| x));
    assert!(choices.iter().any(|&x| !x));
    // the sweep endpoints of the paper exist in the suite
    assert!(FIG4_POINTS.contains(&(28, 512)));
    assert!(FIG4_POINTS.contains(&(1024, 32)));
}

/// The tuner's acceptance gate: the serving path (`plan_for`, tuned) is
/// never slower than the paper's closed-form pick on any suite workload,
/// and strictly faster on at least one per suite — otherwise searching
/// the plan space bought nothing.
#[test]
fn tuned_serving_plans_dominate_paper_plans() {
    let g = gtx_1080ti();
    for (name, suite) in [("fig4", fig4_suite()), ("fig5", fig5_suite())] {
        let mut strictly_better = 0;
        for p in suite {
            let tuned = simulate(&g, &plan_for(&p, &g)).seconds;
            let paper = simulate(&g, &paper_plan_for(&p, &g)).seconds;
            assert!(
                tuned <= paper * (1.0 + 1e-9),
                "{}: tuned {tuned} slower than paper {paper}",
                p.label()
            );
            if tuned < paper * 0.999 {
                strictly_better += 1;
            }
        }
        assert!(strictly_better >= 1, "{name}: tuner never beat the paper's plans");
    }
}
