//! Difftests gating the observability layer's zero-cost contract:
//!
//! 1. `simulate` IS `simulate_detailed(..).result` — bitwise, across
//!    every dispatched plan of the pinned suites and the
//!    batched/decimated/grouped variants, on both testbed GPUs.  The
//!    pinned EXPERIMENTS tables (§3–§11) are all produced through
//!    `simulate`, so this is the bit-identity gate for the whole stack.
//! 2. `execute_batched_traced` returns `execute_batched`'s report
//!    bitwise under BOTH sinks (tracing observes, never changes).
//! 3. `trace::run_traced` with the no-op sink replays the plain
//!    complete_until/submit/drain pump bitwise (completions and stats),
//!    and with a recorder produces a validating, well-formed trace
//!    whose export round-trips the basic Chrome-trace structure.

use pasconv::backend;
use pasconv::conv::suites::{fig4_suite, fig5_suite, model_ops};
use pasconv::fleet::{offered_load, Completion, Fleet, FleetConfig, Policy};
use pasconv::gpusim::{gtx_1080ti, simulate, simulate_detailed, titan_x_maxwell, GpuSpec};
use pasconv::graph::{execute_batched, execute_batched_traced, model_graph, MODEL_NAMES};
use pasconv::trace::{run_traced, Event, NoopSink, Recorder};

fn assert_result_bits(ctx: &str, g: &GpuSpec, plan: &pasconv::gpusim::KernelPlan) {
    let r = simulate(g, plan);
    let b = simulate_detailed(g, plan);
    assert_eq!(r.cycles.to_bits(), b.result.cycles.to_bits(), "{ctx}: cycles");
    assert_eq!(r.seconds.to_bits(), b.result.seconds.to_bits(), "{ctx}: seconds");
    assert_eq!(r.gflops.to_bits(), b.result.gflops.to_bits(), "{ctx}: gflops");
    assert_eq!(
        r.stall_fraction.to_bits(),
        b.result.stall_fraction.to_bits(),
        "{ctx}: stall_fraction"
    );
    assert_eq!(r.bottleneck, b.result.bottleneck, "{ctx}: bottleneck");
}

#[test]
fn simulate_is_detailed_result_bitwise_across_pinned_suites_and_variants() {
    for g in [gtx_1080ti(), titan_x_maxwell()] {
        for p in fig4_suite().into_iter().chain(fig5_suite()) {
            let plan = backend::dispatch_plan(&p, &g);
            assert_result_bits(&format!("{} plain", p.label()), &g, &plan);
            assert_result_bits(&format!("{} xb4", p.label()), &g, &plan.batched(4));
            assert_result_bits(&format!("{} dec", p.label()), &g, &plan.decimated(0.5));
        }
    }
}

#[test]
fn simulate_is_detailed_result_bitwise_across_model_op_plans() {
    // the op-dispatched plans cover strided (decimated), padded and
    // grouped schedules with real model geometry
    let g = gtx_1080ti();
    for (model, ops) in model_ops() {
        for op in ops {
            let plan = backend::dispatch_op_plan(&op, &g);
            assert_result_bits(&format!("{model} {}", op.label()), &g, &plan);
            assert_result_bits(&format!("{model} {} xb8", op.label()), &g, &plan.batched(8));
        }
    }
}

#[test]
fn traced_graph_execution_is_bitwise_identical_under_both_sinks() {
    let g = gtx_1080ti();
    for name in MODEL_NAMES {
        for batch in [1usize, 4] {
            let graph = model_graph(name).unwrap();
            let base = execute_batched(&graph, &g, backend::dispatch_fused_op_plan, batch);
            let mut noop = NoopSink;
            let with_noop = execute_batched_traced(
                &graph,
                &g,
                backend::dispatch_fused_op_plan,
                batch,
                &mut noop,
                0.0,
                name,
            );
            let mut rec = Recorder::new();
            let with_rec = execute_batched_traced(
                &graph,
                &g,
                backend::dispatch_fused_op_plan,
                batch,
                &mut rec,
                0.0,
                name,
            );
            for r in [&with_noop, &with_rec] {
                assert_eq!(
                    base.total_seconds.to_bits(),
                    r.total_seconds.to_bits(),
                    "{name} xb{batch}: total"
                );
                assert_eq!(base.conv_seconds.to_bits(), r.conv_seconds.to_bits());
                assert_eq!(base.glue_seconds.to_bits(), r.glue_seconds.to_bits());
                assert_eq!(base.nodes.len(), r.nodes.len());
                for (x, y) in base.nodes.iter().zip(&r.nodes) {
                    assert_eq!(x.seconds.to_bits(), y.seconds.to_bits(), "{name}: {}", x.name);
                }
            }
            // the recorder saw one root + one child per node, well-formed
            assert_eq!(rec.events().len(), 1 + base.nodes.len(), "{name} xb{batch}");
            rec.validate().unwrap();
        }
    }
}

fn fleet_for(cap_mib: Option<usize>) -> Fleet {
    Fleet::homogeneous(
        4,
        &gtx_1080ti(),
        FleetConfig {
            policy: Policy::LeastLoadedBytes,
            queue_bound: 8,
            capacity_bytes: cap_mib.map(|m| m * 1024 * 1024),
        },
    )
}

fn plain_pump(fleet: &mut Fleet, load: &[pasconv::fleet::Arrival]) -> Vec<Completion> {
    // the exact pre-trace CLI loop
    let mut completions = Vec::with_capacity(load.len());
    for a in load {
        completions.extend(fleet.complete_until(a.t));
        fleet.submit(a.conv, Some(a.model));
    }
    completions.extend(fleet.drain());
    completions
}

#[test]
fn run_traced_with_noop_sink_replays_the_plain_pump_bitwise() {
    for cap in [None, Some(16)] {
        let load = offered_load(192, 3000.0, 0xF1EE7, None);
        let mut f1 = fleet_for(cap);
        let base = plain_pump(&mut f1, &load);
        let mut f2 = fleet_for(cap);
        let mut noop = NoopSink;
        let got = run_traced(&mut f2, &load, &mut noop);
        assert_eq!(base.len(), got.len(), "cap {cap:?}");
        for (x, y) in base.iter().zip(&got) {
            assert_eq!(x.job, y.job);
            assert_eq!(x.device, y.device);
            assert_eq!(x.start.to_bits(), y.start.to_bits());
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
        }
        assert_eq!(f1.stats.accepted, f2.stats.accepted);
        assert_eq!(f1.stats.rejected, f2.stats.rejected);
        assert_eq!(f1.stats.mem_rejected, f2.stats.mem_rejected);
        assert_eq!(f1.now().to_bits(), f2.now().to_bits());
    }
}

#[test]
fn recorded_fleet_trace_validates_and_exports_chrome_json() {
    let load = offered_load(96, 3000.0, 0xF1EE7, None);
    let mut f = fleet_for(Some(24));
    let mut rec = Recorder::new();
    let completions = run_traced(&mut f, &load, &mut rec);
    rec.validate().unwrap();
    pasconv::trace::validate_disjoint(rec.events(), "dev:").unwrap();
    // every completion's request span exists with matching timestamps
    for c in &completions {
        let span = rec
            .events()
            .iter()
            .find_map(|e| match e {
                Event::Span(s) if s.track == format!("req:{}", c.job) && s.name == "request" => {
                    Some(s)
                }
                _ => None,
            })
            .unwrap_or_else(|| panic!("job {} has no request span", c.job));
        assert_eq!(span.t1.to_bits(), c.finish.to_bits());
    }
    let json = rec.chrome_json();
    assert!(json.starts_with('{') && json.ends_with('}'));
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"thread_name\""));
    assert!(json.contains("\"request\""));
}

#[test]
fn prometheus_exposition_matches_metric_counts() {
    let mut m = pasconv::coordinator::Metrics::default();
    m.requests = 42;
    m.record_response("vgg16_b4", 1.5e-3);
    m.record_response("vgg16_b4", 3.0e-3);
    let s = pasconv::trace::exposition(&m);
    assert!(s.contains("pasconv_requests_total 42"));
    assert!(s.contains("pasconv_latency_virtual_seconds_count 2"));
    assert!(s.contains("class=\"vgg16_b4\",quantile=\"0.5\""));
}
