//! Differential tests: pooled execution vs the PR-2 arena planner and
//! the unpooled executor, across every registered model.
//!
//! The contract this file pins:
//!  * memory — the pooled peak (per-tensor alloc-at-def / free-at-last-
//!    use against a shared `DevicePool`) never exceeds the arena plan's
//!    peak, and exactly equals the liveness floor (a pure chain of
//!    exclusive slabs cannot fragment across tensors of one execution);
//!  * time — pooling is a memory-management change ONLY: every node's
//!    simulated seconds and the end-to-end total are bit-identical
//!    (f64::to_bits) to the unpooled `execute_batched` run, warm or
//!    cold pool, any batch;
//!  * isolation — five models sharing one pool sized for the worst
//!    single arena all run, the pool drains to zero, and exhaustion on
//!    an undersized pool is a clean error that poisons nothing.

use pasconv::backend::dispatch_fused_op_plan;
use pasconv::fleet::{DevicePool, PoolError};
use pasconv::gpusim::gtx_1080ti;
use pasconv::graph::{
    execute_batched, execute_pooled, model_graph, plan_arena, topo_order, MODEL_NAMES,
};

#[test]
fn pooled_peak_never_exceeds_arena_peak_on_any_model() {
    let spec = gtx_1080ti();
    for name in MODEL_NAMES {
        let g = model_graph(name).unwrap();
        let arena = plan_arena(&g, &topo_order(&g));
        let mut pool = DevicePool::new(spec.dram_bytes as usize);
        let (_, plan) = execute_pooled(&g, &spec, dispatch_fused_op_plan, 1, &mut pool).unwrap();
        assert!(
            plan.peak_bytes <= arena.peak_bytes,
            "{name}: pooled peak {} above arena peak {}",
            plan.peak_bytes,
            arena.peak_bytes
        );
        // per-tensor granularity sits exactly on the liveness floor —
        // the arena's fragmentation gap is what pooling reclaims
        assert_eq!(plan.peak_bytes, arena.live_peak_bytes(), "{name}: not on the floor");
        assert_eq!(plan.naive_bytes, arena.naive_bytes, "{name}");
        assert_eq!(pool.live_allocs(), 0, "{name}: execution leaked allocations");
        assert_eq!(pool.in_use_slab_bytes(), 0, "{name}");
    }
}

#[test]
fn pooled_timings_bit_identical_on_any_model_and_batch() {
    let spec = gtx_1080ti();
    for name in MODEL_NAMES {
        let g = model_graph(name).unwrap();
        for batch in [1usize, 4] {
            let plain = execute_batched(&g, &spec, dispatch_fused_op_plan, batch);
            let mut pool = DevicePool::new(spec.dram_bytes as usize);
            let (pooled, _) =
                execute_pooled(&g, &spec, dispatch_fused_op_plan, batch, &mut pool).unwrap();
            assert_eq!(
                pooled.total_seconds.to_bits(),
                plain.total_seconds.to_bits(),
                "{name} b{batch}: total drifted"
            );
            assert_eq!(pooled.nodes.len(), plain.nodes.len(), "{name} b{batch}");
            for (a, b) in pooled.nodes.iter().zip(&plain.nodes) {
                assert_eq!(a.id, b.id, "{name} b{batch}: schedule changed");
                assert_eq!(
                    a.seconds.to_bits(),
                    b.seconds.to_bits(),
                    "{name} b{batch}: node {} drifted",
                    a.name
                );
            }
            assert_eq!(
                pooled.conv_seconds.to_bits(),
                plain.conv_seconds.to_bits(),
                "{name} b{batch}"
            );
        }
    }
}

#[test]
fn warm_pool_reexecution_is_all_reuse_and_still_bit_identical() {
    let spec = gtx_1080ti();
    let g = model_graph("resnet18").unwrap();
    let mut pool = DevicePool::new(spec.dram_bytes as usize);
    let (cold_report, cold) = execute_pooled(&g, &spec, dispatch_fused_op_plan, 1, &mut pool).unwrap();
    let (warm_report, warm) = execute_pooled(&g, &spec, dispatch_fused_op_plan, 1, &mut pool).unwrap();
    assert_eq!(warm.peak_bytes, cold.peak_bytes);
    assert_eq!(warm.allocs, cold.allocs);
    // every tensor shape was parked by run one: run two carves nothing
    assert_eq!(warm.reuse_hits, warm.allocs, "warm pool should serve entirely from reuse");
    assert_eq!(warm_report.total_seconds.to_bits(), cold_report.total_seconds.to_bits());
    assert_eq!(pool.stats.frees, pool.stats.allocs, "both executions fully released");
}

#[test]
fn five_models_share_one_pool_sized_for_the_worst_arena() {
    let spec = gtx_1080ti();
    // the cap a single-arena deployment would have provisioned anyway
    let worst_arena = MODEL_NAMES
        .iter()
        .map(|name| {
            let g = model_graph(name).unwrap();
            plan_arena(&g, &topo_order(&g)).peak_bytes
        })
        .max()
        .unwrap();
    let mut pool = DevicePool::new(worst_arena);
    for name in MODEL_NAMES {
        let g = model_graph(name).unwrap();
        let (_, plan) = execute_pooled(&g, &spec, dispatch_fused_op_plan, 1, &mut pool)
            .unwrap_or_else(|e| panic!("{name} must fit a worst-arena pool: {e}"));
        assert!(plan.peak_bytes <= worst_arena, "{name}");
        assert!(pool.slab_bytes() <= pool.capacity(), "{name}: cap burst");
        assert_eq!(pool.in_use_slab_bytes(), 0, "{name}: residue left behind");
    }
    // parked slabs are reclaimable in full
    let parked = pool.slab_bytes();
    let reclaimed = pool.evict_free();
    assert_eq!(reclaimed, parked, "trim must reclaim every parked byte");
    assert_eq!(pool.slab_bytes(), 0, "trim must empty an idle pool");
}

#[test]
fn exhaustion_is_a_clean_error_not_a_poisoned_pool() {
    let spec = gtx_1080ti();
    let vgg = model_graph("vgg16").unwrap();
    let mut pool = DevicePool::new(1 << 20); // 1 MiB: far below vgg16's floor
    match execute_pooled(&vgg, &spec, dispatch_fused_op_plan, 1, &mut pool) {
        Err(PoolError::Exhausted { capacity, .. }) => assert_eq!(capacity, 1 << 20),
        other => panic!("undersized pool must exhaust, got {other:?}"),
    }
    assert_eq!(pool.live_allocs(), 0, "failed execution rolled back");
    assert_eq!(pool.in_use_slab_bytes(), 0);
    // the same pool still serves work that fits — no deadlock, no poison
    let mut b = pasconv::graph::GraphBuilder::new("tiny");
    let x = b.input("in", pasconv::graph::Shape::new(8, 14, 14));
    b.conv_same("c0", x, pasconv::conv::ConvProblem::multi(8, 14, 8, 3)).unwrap();
    let tiny = b.finish().unwrap();
    let (_, plan) = execute_pooled(&tiny, &spec, dispatch_fused_op_plan, 1, &mut pool).unwrap();
    assert!(plan.peak_bytes <= pool.capacity());
}
