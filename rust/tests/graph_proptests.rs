//! Property-based tests (util::prop harness) over the graph subsystem:
//! random valid DAGs built through `GraphBuilder` (dense, 'same',
//! strided and grouped conv ops mixed in), checked against the
//! invariants — topological order respects edges, shape inference
//! matches `ConvOp` output dims, the arena plan never
//! overlaps two simultaneously-live tensors, and the planned peak never
//! exceeds the naive sum of tensors.

use pasconv::conv::{ConvOp, ConvProblem};
use pasconv::graph::{
    model_graph, plan_arena, topo_order, Graph, GraphBuilder, NodeId, Op, Shape, ARENA_ALIGN,
    MODEL_NAMES,
};
use pasconv::util::prop::{check_no_shrink, Config};
use pasconv::util::rng::Rng;

/// Random valid DAG: square maps throughout, every op drawn so its
/// shape rule holds by construction (the builder re-validates).
fn random_graph(r: &mut Rng) -> Graph {
    let mut b = GraphBuilder::new("prop");
    let c0 = *r.choose(&[1usize, 4, 8, 16]);
    let w0 = *r.choose(&[14usize, 28, 32, 56]);
    let mut ids: Vec<NodeId> = vec![b.input("in", Shape::new(c0, w0, w0))];
    let ops = r.range_usize(1, 14);
    for i in 0..ops {
        let src = *r.choose(&ids);
        let s = b.node_shape(src);
        let id = match r.range_usize(0, 4) {
            0 => {
                // conv on the source's exact shape
                let ks: Vec<usize> =
                    [1usize, 3, 5].into_iter().filter(|&k| k <= s.h.min(s.w)).collect();
                let k = *r.choose(&ks);
                let m = *r.choose(&[4usize, 8, 16, 32]);
                let p = ConvProblem { c: s.c, wy: s.h, wx: s.w, m, k };
                match r.range_usize(0, 3) {
                    0 => b.conv(&format!("conv{i}"), src, p).unwrap(),
                    1 => b.conv_same(&format!("conv{i}"), src, p).unwrap(),
                    2 if k % 2 == 1 && s.h >= 2 && s.w >= 2 => {
                        // native stride-2 downsampling op
                        let op = ConvOp::strided(p, 2, (k - 1) / 2);
                        b.conv_op(&format!("conv{i}"), src, op).unwrap()
                    }
                    _ if s.c % 4 == 0 && m % 4 == 0 => {
                        // grouped op (4 groups)
                        let op = ConvOp { core: p, stride: 1, pad: 0, groups: 4 };
                        b.conv_op(&format!("conv{i}"), src, op).unwrap()
                    }
                    _ => b.conv(&format!("conv{i}"), src, p).unwrap(),
                }
            }
            1 => {
                let grow = *r.choose(&[0usize, 1, 2, 4]);
                b.pad(&format!("pad{i}"), src, s.h + grow, s.w + grow).unwrap()
            }
            2 => {
                if s.h >= 3 && s.w >= 3 {
                    let k = *r.choose(&[2usize, 3]);
                    let stride = *r.choose(&[1usize, 2]);
                    b.pool(&format!("pool{i}"), src, k, stride).unwrap()
                } else {
                    b.pad(&format!("pad{i}"), src, s.h, s.w).unwrap()
                }
            }
            3 => {
                // a same-shape sibling via identity pad, then a skip add
                let twin = b.pad(&format!("twin{i}"), src, s.h, s.w).unwrap();
                b.add_skip(&format!("add{i}"), src, twin).unwrap()
            }
            _ => {
                // concat every earlier node sharing this map size (>= 2)
                let peers: Vec<NodeId> = ids
                    .iter()
                    .copied()
                    .filter(|&p| {
                        let ps = b.node_shape(p);
                        ps.h == s.h && ps.w == s.w
                    })
                    .take(3)
                    .collect();
                if peers.len() >= 2 {
                    b.concat(&format!("cat{i}"), &peers).unwrap()
                } else {
                    b.pad(&format!("pad{i}"), src, s.h, s.w).unwrap()
                }
            }
        };
        ids.push(id);
    }
    b.finish().unwrap()
}

#[test]
fn prop_random_graphs_validate() {
    check_no_shrink(&Config { cases: 96, seed: 31 }, random_graph, |g| {
        g.validate().map_err(|e| format!("{e:#}"))
    });
}

#[test]
fn prop_topo_order_respects_edges() {
    check_no_shrink(&Config { cases: 96, seed: 32 }, random_graph, |g| {
        let order = topo_order(g);
        if order.len() != g.len() {
            return Err(format!("order has {} of {} nodes", order.len(), g.len()));
        }
        let mut pos = vec![usize::MAX; g.len()];
        for (i, &id) in order.iter().enumerate() {
            if pos[id] != usize::MAX {
                return Err(format!("node {id} scheduled twice"));
            }
            pos[id] = i;
        }
        for n in g.nodes() {
            for &i in &n.inputs {
                if pos[i] >= pos[n.id] {
                    return Err(format!("{}: input {} not scheduled before it", n.name, i));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shape_inference_matches_conv_problem_dims() {
    check_no_shrink(&Config { cases: 96, seed: 33 }, random_graph, |g| {
        for n in g.nodes() {
            if let Op::Conv { conv } = &n.op {
                let want = Shape::new(conv.core.m, conv.oy(), conv.ox());
                if n.shape != want {
                    return Err(format!(
                        "{}: conv shape {} != problem output {}",
                        n.name,
                        n.shape.label(),
                        want.label()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_arena_never_overlaps_live_tensors() {
    check_no_shrink(&Config { cases: 96, seed: 34 }, random_graph, |g| {
        let plan = plan_arena(g, &topo_order(g));
        for (i, a) in plan.placements.iter().enumerate() {
            if a.offset % ARENA_ALIGN != 0 {
                return Err(format!("node {}: unaligned offset {}", a.life.id, a.offset));
            }
            for b in &plan.placements[i + 1..] {
                if a.life.overlaps(&b.life) {
                    let disjoint = a.offset + a.life.bytes <= b.offset
                        || b.offset + b.life.bytes <= a.offset;
                    if !disjoint {
                        return Err(format!(
                            "nodes {} and {} share arena bytes while both live",
                            a.life.id, b.life.id
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_arena_peak_bounded() {
    check_no_shrink(&Config { cases: 96, seed: 35 }, random_graph, |g| {
        let plan = plan_arena(g, &topo_order(g));
        if plan.peak_bytes > plan.naive_bytes {
            return Err(format!(
                "peak {} exceeds naive sum {}",
                plan.peak_bytes, plan.naive_bytes
            ));
        }
        let floor = plan.live_peak_bytes();
        if plan.peak_bytes < floor {
            return Err(format!("peak {} below live floor {floor}", plan.peak_bytes));
        }
        Ok(())
    });
}

#[test]
fn model_graphs_satisfy_every_property() {
    // the five registered models are the graphs that matter: run the
    // same invariants on them directly
    for name in MODEL_NAMES {
        let g = model_graph(name).unwrap();
        g.validate().unwrap();
        let order = topo_order(&g);
        let mut pos = vec![usize::MAX; g.len()];
        for (i, &id) in order.iter().enumerate() {
            pos[id] = i;
        }
        for n in g.nodes() {
            for &i in &n.inputs {
                assert!(pos[i] < pos[n.id], "{name}/{}", n.name);
            }
            if let Op::Conv { conv } = &n.op {
                assert_eq!(n.shape, Shape::new(conv.core.m, conv.oy(), conv.ox()));
            }
        }
        let plan = plan_arena(&g, &order);
        assert!(plan.peak_bytes <= plan.naive_bytes, "{name}");
        assert!(plan.peak_bytes >= plan.live_peak_bytes(), "{name}");
    }
}
