//! Property-based tests (util::prop harness) over the graph subsystem:
//! random valid DAGs built through `GraphBuilder` (dense, 'same',
//! strided and grouped conv ops mixed in), checked against the
//! invariants — topological order respects edges, shape inference
//! matches `ConvOp` output dims, the arena plan never
//! overlaps two simultaneously-live tensors, and the planned peak never
//! exceeds the naive sum of tensors.

use pasconv::conv::{ConvOp, ConvProblem};
use pasconv::gpusim::gtx_1080ti;
use pasconv::graph::{
    execute, fuse, model_graph, plan_arena, reference_output, topo_order, zero_copy_aliases,
    Graph, GraphBuilder, NodeId, Op, Shape, ARENA_ALIGN, MODEL_NAMES,
};
use pasconv::plans::paper_op_plan_for;
use pasconv::util::prop::{check_no_shrink, Config};
use pasconv::util::rng::Rng;

/// Random valid DAG: square maps throughout, every op drawn so its
/// shape rule holds by construction (the builder re-validates).
fn random_graph(r: &mut Rng) -> Graph {
    let mut b = GraphBuilder::new("prop");
    let c0 = *r.choose(&[1usize, 4, 8, 16]);
    let w0 = *r.choose(&[14usize, 28, 32, 56]);
    let mut ids: Vec<NodeId> = vec![b.input("in", Shape::new(c0, w0, w0))];
    let ops = r.range_usize(1, 14);
    for i in 0..ops {
        let src = *r.choose(&ids);
        let s = b.node_shape(src);
        let id = match r.range_usize(0, 4) {
            0 => {
                // conv on the source's exact shape
                let ks: Vec<usize> =
                    [1usize, 3, 5].into_iter().filter(|&k| k <= s.h.min(s.w)).collect();
                let k = *r.choose(&ks);
                let m = *r.choose(&[4usize, 8, 16, 32]);
                let p = ConvProblem { c: s.c, wy: s.h, wx: s.w, m, k };
                match r.range_usize(0, 3) {
                    0 => b.conv(&format!("conv{i}"), src, p).unwrap(),
                    1 => b.conv_same(&format!("conv{i}"), src, p).unwrap(),
                    2 if k % 2 == 1 && s.h >= 2 && s.w >= 2 => {
                        // native stride-2 downsampling op
                        let op = ConvOp::strided(p, 2, (k - 1) / 2);
                        b.conv_op(&format!("conv{i}"), src, op).unwrap()
                    }
                    _ if s.c % 4 == 0 && m % 4 == 0 => {
                        // grouped op (4 groups)
                        let op = ConvOp { core: p, stride: 1, pad: 0, groups: 4 };
                        b.conv_op(&format!("conv{i}"), src, op).unwrap()
                    }
                    _ => b.conv(&format!("conv{i}"), src, p).unwrap(),
                }
            }
            1 => {
                let grow = *r.choose(&[0usize, 1, 2, 4]);
                b.pad(&format!("pad{i}"), src, s.h + grow, s.w + grow).unwrap()
            }
            2 => {
                if s.h >= 3 && s.w >= 3 {
                    let k = *r.choose(&[2usize, 3]);
                    let stride = *r.choose(&[1usize, 2]);
                    b.pool(&format!("pool{i}"), src, k, stride).unwrap()
                } else {
                    b.pad(&format!("pad{i}"), src, s.h, s.w).unwrap()
                }
            }
            3 => {
                // a same-shape sibling via identity pad, then a skip add
                let twin = b.pad(&format!("twin{i}"), src, s.h, s.w).unwrap();
                b.add_skip(&format!("add{i}"), src, twin).unwrap()
            }
            _ => {
                // concat every earlier node sharing this map size (>= 2)
                let peers: Vec<NodeId> = ids
                    .iter()
                    .copied()
                    .filter(|&p| {
                        let ps = b.node_shape(p);
                        ps.h == s.h && ps.w == s.w
                    })
                    .take(3)
                    .collect();
                if peers.len() >= 2 {
                    b.concat(&format!("cat{i}"), &peers).unwrap()
                } else {
                    b.pad(&format!("pad{i}"), src, s.h, s.w).unwrap()
                }
            }
        };
        ids.push(id);
    }
    b.finish().unwrap()
}

#[test]
fn prop_random_graphs_validate() {
    check_no_shrink(&Config { cases: 96, seed: 31 }, random_graph, |g| {
        g.validate().map_err(|e| format!("{e:#}"))
    });
}

#[test]
fn prop_topo_order_respects_edges() {
    check_no_shrink(&Config { cases: 96, seed: 32 }, random_graph, |g| {
        let order = topo_order(g);
        if order.len() != g.len() {
            return Err(format!("order has {} of {} nodes", order.len(), g.len()));
        }
        let mut pos = vec![usize::MAX; g.len()];
        for (i, &id) in order.iter().enumerate() {
            if pos[id] != usize::MAX {
                return Err(format!("node {id} scheduled twice"));
            }
            pos[id] = i;
        }
        for n in g.nodes() {
            for &i in &n.inputs {
                if pos[i] >= pos[n.id] {
                    return Err(format!("{}: input {} not scheduled before it", n.name, i));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shape_inference_matches_conv_problem_dims() {
    check_no_shrink(&Config { cases: 96, seed: 33 }, random_graph, |g| {
        for n in g.nodes() {
            if let Op::Conv { conv, epilogue: _ } = &n.op {
                let want = Shape::new(conv.core.m, conv.oy(), conv.ox());
                if n.shape != want {
                    return Err(format!(
                        "{}: conv shape {} != problem output {}",
                        n.name,
                        n.shape.label(),
                        want.label()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_arena_never_overlaps_live_tensors() {
    check_no_shrink(&Config { cases: 96, seed: 34 }, random_graph, |g| {
        let plan = plan_arena(g, &topo_order(g));
        for (i, a) in plan.placements.iter().enumerate() {
            if a.offset % ARENA_ALIGN != 0 {
                return Err(format!("node {}: unaligned offset {}", a.life.id, a.offset));
            }
            for b in &plan.placements[i + 1..] {
                if a.life.overlaps(&b.life) {
                    let disjoint = a.offset + a.life.bytes <= b.offset
                        || b.offset + b.life.bytes <= a.offset;
                    if !disjoint {
                        return Err(format!(
                            "nodes {} and {} share arena bytes while both live",
                            a.life.id, b.life.id
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_arena_peak_bounded() {
    check_no_shrink(&Config { cases: 96, seed: 35 }, random_graph, |g| {
        let plan = plan_arena(g, &topo_order(g));
        if plan.peak_bytes > plan.naive_bytes {
            return Err(format!(
                "peak {} exceeds naive sum {}",
                plan.peak_bytes, plan.naive_bytes
            ));
        }
        let floor = plan.live_peak_bytes();
        if plan.peak_bytes < floor {
            return Err(format!("peak {} below live floor {floor}", plan.peak_bytes));
        }
        Ok(())
    });
}

/// Small random graph biased toward the fusion pass's patterns
/// (conv→relu, conv→relu→pool, add(·, conv), concat-of-convs), with
/// maps tiny enough that the CPU reference executor stays cheap.  Ends
/// in an identity pad sink: the pad is never fused, so the graph's
/// reference output (its last node) survives rewriting and pins the
/// value of everything upstream.
fn small_fusable_graph(r: &mut Rng) -> Graph {
    let mut b = GraphBuilder::new("fuseprop");
    let c0 = *r.choose(&[1usize, 2, 4]);
    let w0 = *r.choose(&[6usize, 8, 10]);
    let mut last = b.input("in", Shape::new(c0, w0, w0));
    let mut ids: Vec<NodeId> = vec![last];
    let ops = r.range_usize(2, 6);
    for i in 0..ops {
        let src = *r.choose(&ids);
        let s = b.node_shape(src);
        let conv_p = |m: usize| ConvProblem { c: s.c, wy: s.h, wx: s.w, m, k: 3 };
        last = match r.range_usize(0, 4) {
            0 => {
                // conv -> relu tail
                let c = b.conv_same(&format!("c{i}"), src, conv_p(*r.choose(&[2usize, 4, 8]))).unwrap();
                b.relu(&format!("c{i}.relu"), c).unwrap()
            }
            1 if s.h >= 2 && s.w >= 2 => {
                // conv -> relu -> pool chain (the through-relu rewrite)
                let c = b.conv_same(&format!("p{i}"), src, conv_p(*r.choose(&[2usize, 4]))).unwrap();
                let rl = b.relu(&format!("p{i}.relu"), c).unwrap();
                b.pool(&format!("p{i}.pool"), rl, 2, 2).unwrap()
            }
            2 => {
                // residual: add(src, conv(src)) — conv is the second
                // operand, exercising the commuted fold
                let c = b.conv_same(&format!("r{i}"), src, conv_p(s.c)).unwrap();
                b.add_skip(&format!("r{i}.add"), src, c).unwrap()
            }
            3 => {
                // concat of two sibling convs — the zero-copy candidate
                let a = b.conv_same(&format!("a{i}"), src, conv_p(*r.choose(&[2usize, 4]))).unwrap();
                let c = b.conv_same(&format!("b{i}"), src, conv_p(*r.choose(&[2usize, 4]))).unwrap();
                b.concat(&format!("cat{i}"), &[a, c]).unwrap()
            }
            _ => {
                // plain glue that the pass must leave alone
                b.pad(&format!("pad{i}"), src, s.h + 2, s.w + 2).unwrap()
            }
        };
        ids.push(last);
    }
    let s = b.node_shape(last);
    b.pad("sink", last, s.h, s.w).unwrap();
    b.finish().unwrap()
}

#[test]
fn prop_fusion_preserves_reference_semantics() {
    // the tentpole's correctness bar: rewriting a graph through the
    // fusion pass never changes the numbers — fused epilogues are
    // bit-identical to the glue ops they replace (strict relu, strict
    // max fold, commutative residual add, placement-only concat)
    let spec = gtx_1080ti();
    check_no_shrink(&Config { cases: 48, seed: 36 }, small_fusable_graph, |g| {
        let (fg, rep) = fuse(g, &spec, paper_op_plan_for);
        fg.validate().map_err(|e| format!("fused graph invalid: {e:#}"))?;
        let want = reference_output(g);
        let got = reference_output(&fg);
        if want.len() != got.len() {
            return Err(format!("output elems {} != {}", got.len(), want.len()));
        }
        for (i, (w, f)) in want.iter().zip(&got).enumerate() {
            if w.to_bits() != f.to_bits() {
                return Err(format!(
                    "elem {i}: fused {f} != unfused {w} ({} nodes fused)",
                    rep.nodes_fused
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fusion_never_loses_cycles() {
    // the dispatcher's structural floor, as a property: the fused graph
    // executes no slower than the unfused one, and never grows glue
    let spec = gtx_1080ti();
    check_no_shrink(&Config { cases: 48, seed: 37 }, random_graph, |g| {
        let (fg, rep) = fuse(g, &spec, paper_op_plan_for);
        let base = execute(g, &spec, paper_op_plan_for);
        let fused = execute(&fg, &spec, paper_op_plan_for);
        if fused.total_seconds > base.total_seconds * (1.0 + 1e-9) {
            return Err(format!(
                "fused {} > unfused {} ({} nodes fused)",
                fused.total_seconds, base.total_seconds, rep.nodes_fused
            ));
        }
        if fused.glue_seconds > base.glue_seconds * (1.0 + 1e-9) {
            return Err(format!(
                "fusion grew glue: {} > {}",
                fused.glue_seconds, base.glue_seconds
            ));
        }
        if rep.nodes_fused == 0 && fg.len() != g.len() {
            return Err("report says nothing fused but the graph shrank".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_zero_copy_concat_placements_are_disjoint_aligned_subranges() {
    // every producer aliased into a zero-copy concat sits at an
    // ARENA_ALIGN-aligned offset, inside the concat allocation, and no
    // two producers of the same concat overlap
    let spec = gtx_1080ti();
    check_no_shrink(&Config { cases: 64, seed: 38 }, small_fusable_graph, |g| {
        let (fg, _) = fuse(g, &spec, paper_op_plan_for);
        let aliases = zero_copy_aliases(&fg);
        let mut by_cat: std::collections::HashMap<NodeId, Vec<(usize, usize)>> =
            std::collections::HashMap::new();
        for (&prod, &(cat, off)) in &aliases {
            let bytes = fg.node(prod).shape.bytes();
            let cat_bytes = fg.node(cat).shape.bytes();
            if off % ARENA_ALIGN != 0 {
                return Err(format!("producer {prod}: unaligned offset {off}"));
            }
            if off + bytes > cat_bytes {
                return Err(format!(
                    "producer {prod}: [{off}, {}) outside concat's {cat_bytes} bytes",
                    off + bytes
                ));
            }
            by_cat.entry(cat).or_default().push((off, bytes));
        }
        for (cat, mut ranges) in by_cat {
            ranges.sort_unstable();
            for w in ranges.windows(2) {
                if w[0].0 + w[0].1 > w[1].0 {
                    return Err(format!("concat {cat}: producer sub-ranges overlap"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn model_graphs_satisfy_every_property() {
    // the five registered models are the graphs that matter: run the
    // same invariants on them directly
    for name in MODEL_NAMES {
        let g = model_graph(name).unwrap();
        g.validate().unwrap();
        let order = topo_order(&g);
        let mut pos = vec![usize::MAX; g.len()];
        for (i, &id) in order.iter().enumerate() {
            pos[id] = i;
        }
        for n in g.nodes() {
            for &i in &n.inputs {
                assert!(pos[i] < pos[n.id], "{name}/{}", n.name);
            }
            if let Op::Conv { conv, epilogue: _ } = &n.op {
                assert_eq!(n.shape, Shape::new(conv.core.m, conv.oy(), conv.ox()));
            }
        }
        let plan = plan_arena(&g, &order);
        assert!(plan.peak_bytes <= plan.naive_bytes, "{name}");
        assert!(plan.peak_bytes >= plan.live_peak_bytes(), "{name}");
    }
}
