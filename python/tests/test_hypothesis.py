"""Property-based sweeps over kernel shapes/dtypes (hypothesis).

Deliverable (c): hypothesis drives the Pallas kernels across the shape
space (including every divisor-tiling the wrappers may pick) and asserts
allclose against the pure-jnp oracle.  Sizes are kept CPU-tractable;
interpret-mode Pallas is slow, correctness is the point here.
"""

import numpy as np
import pytest

import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from compile.kernels import conv2d_im2col, conv2d_multi, conv2d_single, ref

COMMON = dict(
    deadline=None,
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


def arr(shape, seed, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape).astype(dtype))


@st.composite
def single_case(draw):
    k = draw(st.sampled_from([1, 2, 3, 5]))
    wy = draw(st.integers(k, 24))
    wx = draw(st.integers(k, 24))
    m = draw(st.integers(1, 12))
    seed = draw(st.integers(0, 2**31 - 1))
    return wy, wx, m, k, seed


@st.composite
def multi_case(draw):
    k = draw(st.sampled_from([1, 2, 3, 5]))
    wy = draw(st.integers(k, 16))
    wx = draw(st.integers(k, 16))
    c = draw(st.sampled_from([1, 2, 3, 4, 6, 8, 16]))
    m = draw(st.integers(1, 10))
    seed = draw(st.integers(0, 2**31 - 1))
    return c, wy, wx, m, k, seed


@given(single_case())
@settings(**COMMON)
def test_single_kernel_property(case):
    wy, wx, m, k, seed = case
    img, flt = arr((wy, wx), seed), arr((m, k, k), seed + 1)
    got = conv2d_single(img, flt)
    want = ref.conv2d_single_ref(img, flt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(multi_case())
@settings(**COMMON)
def test_multi_kernel_property(case):
    c, wy, wx, m, k, seed = case
    img, flt = arr((c, wy, wx), seed), arr((m, c, k, k), seed + 1)
    got = conv2d_multi(img, flt)
    want = ref.conv2d_multi_ref(img, flt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(multi_case())
@settings(**COMMON, )
def test_im2col_kernel_property(case):
    c, wy, wx, m, k, seed = case
    img, flt = arr((c, wy, wx), seed), arr((m, c, k, k), seed + 1)
    got = conv2d_im2col(img, flt)
    want = ref.conv2d_multi_ref(img, flt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(multi_case(), st.sampled_from([32, 64, 128]))
@settings(**COMMON)
def test_multi_segment_bytes_property(case, segment_bytes):
    """The S knob must never change numerics, only the schedule."""
    c, wy, wx, m, k, seed = case
    img, flt = arr((c, wy, wx), seed), arr((m, c, k, k), seed + 1)
    got = conv2d_multi(img, flt, segment_bytes=segment_bytes)
    want = ref.conv2d_multi_ref(img, flt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(single_case())
@settings(**COMMON)
def test_single_linearity_property(case):
    """Convolution is linear: conv(a*I, F) == a * conv(I, F)."""
    wy, wx, m, k, seed = case
    img, flt = arr((wy, wx), seed), arr((m, k, k), seed + 1)
    got = conv2d_single(2.5 * img, flt)
    want = 2.5 * ref.conv2d_single_ref(img, flt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(multi_case())
@settings(**COMMON)
def test_multi_channel_additivity_property(case):
    """Eq. (1) decomposes over channels: conv(I, F) == sum_ch conv(I_ch, F_ch)."""
    c, wy, wx, m, k, seed = case
    img, flt = arr((c, wy, wx), seed), arr((m, c, k, k), seed + 1)
    whole = conv2d_multi(img, flt)
    parts = sum(
        ref.conv2d_multi_ref(img[ch:ch + 1], flt[:, ch:ch + 1]) for ch in range(c)
    )
    np.testing.assert_allclose(whole, parts, rtol=1e-3, atol=1e-3)
