"""AOT pipeline tests: lowering, HLO-text interchange, manifest format."""

import os
import re

import pytest

from compile import aot, model


def test_catalog_names_unique_and_complete():
    names = [name for name, _, _ in aot.catalog()]
    assert len(names) == len(set(names))
    kinds = {meta["kind"] for _, _, meta in aot.catalog()}
    assert kinds == {
        "conv_single",
        "conv_multi",
        "conv_im2col",
        "conv_winograd",
        "conv_fft",
        "cnn",
    }
    assert "papernet_b1" in names and "papernet_b8" in names


def test_catalog_metadata_matches_specs():
    for name, fn, meta in aot.catalog():
        if meta["kind"] == "conv_single":
            assert fn.arg_specs[0].shape == (meta["wy"], meta["wx"])
            assert fn.arg_specs[1].shape == (meta["m"], meta["k"], meta["k"])
        elif meta["kind"] in ("conv_multi", "conv_im2col"):
            assert fn.arg_specs[0].shape == (meta["c"], meta["wy"], meta["wx"])
            assert fn.arg_specs[1].shape == (meta["m"], meta["c"], meta["k"], meta["k"])
        elif meta["kind"] == "cnn":
            assert fn.arg_specs[0].shape == (meta["batch"], 1, 28, 28)


def test_lower_one_emits_hlo_text():
    """The interchange gotcha: must be HLO *text* with an ENTRY computation,
    parseable by xla_extension 0.5.1 (no 64-bit-id protos)."""
    fn = model.make_conv_single(8, 8, 2, 3)
    text = aot.lower_one(fn)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: the root is a tuple — rust unwraps with to_tuple1()
    assert re.search(r"ROOT.*tuple", text)


def test_main_writes_artifacts_and_manifest(tmp_path):
    rc = aot.main(["--out", str(tmp_path), "--only", "single_w32_m32_k3"])
    assert rc == 0
    assert (tmp_path / "single_w32_m32_k3.hlo.txt").exists()


def test_manifest_lines_parseable():
    """Each manifest line must be whitespace-separated key=value fields —
    the exact grammar rust/src/runtime/manifest.rs implements."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.txt")
    if not os.path.exists(art):
        pytest.skip("artifacts not built")
    with open(art) as f:
        lines = [l.strip() for l in f if l.strip() and not l.startswith("#")]
    assert lines, "manifest empty"
    for line in lines:
        fields = dict(tok.split("=", 1) for tok in line.split())
        assert "name" in fields and "file" in fields and "kind" in fields
        assert fields["file"].endswith(".hlo.txt")


def test_lowered_text_keeps_large_constants():
    """Regression: the default HLO printer elides big literals as
    constant({...}) and the rust parser reads them back as ZEROS —
    PaperNet's baked weights vanished this way once. The AOT path must
    print large constants."""
    fn = model.make_papernet(batch=1)
    text = aot.lower_one(fn)
    assert "{...}" not in text, "elided constants would parse back as zeros"


def test_built_artifacts_have_no_elided_constants():
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.exists(os.path.join(art_dir, "manifest.txt")):
        pytest.skip("artifacts not built")
    for name in os.listdir(art_dir):
        if name.endswith(".hlo.txt"):
            with open(os.path.join(art_dir, name)) as f:
                assert "{...}" not in f.read(), f"{name} has elided constants"
