"""L2 model tests: conv services and the PaperNet serving workload."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------------------
# conv service factories
# ---------------------------------------------------------------------------

def test_make_conv_single_spec_and_value():
    fn = model.make_conv_single(12, 12, 4, 3)
    (img_spec, flt_spec) = fn.arg_specs
    assert img_spec.shape == (12, 12) and flt_spec.shape == (4, 3, 3)
    img, flt = rand((12, 12), 0), rand((4, 3, 3), 1)
    (out,) = fn(img, flt)
    np.testing.assert_allclose(out, ref.conv2d_single_ref(img, flt), rtol=1e-4, atol=1e-4)


def test_make_conv_multi_spec_and_value():
    fn = model.make_conv_multi(8, 10, 10, 4, 3)
    img, flt = rand((8, 10, 10), 2), rand((4, 8, 3, 3), 3)
    (out,) = fn(img, flt)
    np.testing.assert_allclose(out, ref.conv2d_multi_ref(img, flt), rtol=1e-4, atol=1e-4)


def test_make_conv_im2col_matches_multi():
    f1 = model.make_conv_multi(8, 10, 10, 4, 3)
    f2 = model.make_conv_im2col(8, 10, 10, 4, 3)
    img, flt = rand((8, 10, 10), 4), rand((4, 8, 3, 3), 5)
    np.testing.assert_allclose(f1(img, flt)[0], f2(img, flt)[0], rtol=1e-4, atol=1e-4)


def test_conv_service_jits():
    fn = model.make_conv_single(8, 8, 2, 3)
    jitted = jax.jit(fn)
    img, flt = rand((8, 8), 6), rand((2, 3, 3), 7)
    np.testing.assert_allclose(jitted(img, flt)[0], fn(img, flt)[0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# PaperNet
# ---------------------------------------------------------------------------

def test_papernet_params_deterministic():
    p1, p2 = model.papernet_params(0), model.papernet_params(0)
    for k in p1:
        np.testing.assert_array_equal(p1[k][0], p2[k][0])
    p3 = model.papernet_params(1)
    assert not np.allclose(p1["conv0"][0], p3["conv0"][0])


def test_papernet_layer_shapes():
    """Walk the documented map-size chain 28->24->12->10->5->5->3."""
    params = model.papernet_params()
    for idx, (kind, c, m, k) in enumerate(model.PAPERNET_LAYERS):
        w, b = params[f"conv{idx}"]
        assert w.shape == (m, c, k, k) and b.shape == (m,)


def test_papernet_apply_logits():
    params = model.papernet_params()
    img = rand((1, 28, 28), 8)
    logits = model.papernet_apply(params, img)
    assert logits.shape == (10,)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_papernet_batch_consistency():
    """vmap'd batched forward == per-image forward."""
    fn = model.make_papernet(batch=4)
    imgs = rand((4, 1, 28, 28), 9)
    (batched,) = fn(imgs)
    params = model.papernet_params()
    single = jnp.stack([model.papernet_apply(params, imgs[i]) for i in range(4)])
    np.testing.assert_allclose(batched, single, rtol=1e-4, atol=1e-4)


def test_papernet_input_sensitivity():
    """Different images must produce different logits (weights not degenerate)."""
    fn = model.make_papernet(batch=2)
    imgs = jnp.stack([rand((1, 28, 28), 10), rand((1, 28, 28), 11)])
    (logits,) = fn(imgs)
    assert not np.allclose(logits[0], logits[1])


def test_pool2():
    x = jnp.arange(16.0).reshape(1, 4, 4)
    out = model._pool2(x)
    np.testing.assert_allclose(out[0], [[5.0, 7.0], [13.0, 15.0]])


def test_pool2_odd_sizes_truncate():
    x = jnp.arange(25.0).reshape(1, 5, 5)
    out = model._pool2(x)
    assert out.shape == (1, 2, 2)
    np.testing.assert_allclose(out[0], [[6.0, 8.0], [16.0, 18.0]])
