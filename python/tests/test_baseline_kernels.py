"""Winograd (§1 category 3) and FFT (§1 category 2) baseline kernels vs
the direct oracle — all four convolution families must agree numerically."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import (
    conv2d_fft,
    conv2d_im2col,
    conv2d_multi,
    conv2d_winograd,
    ref,
)

RTOL, ATOL = 1e-3, 1e-3


def rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------------------
# Winograd F(2x2, 3x3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,wy,wx,m", [
    (1, 6, 6, 2),
    (4, 12, 12, 6),
    (8, 7, 7, 8),     # odd output (5x5) -> pad + crop path
    (16, 14, 15, 8),  # non-square, mixed parity
    (3, 10, 10, 5),
])
def test_winograd_matches_ref(c, wy, wx, m):
    img, flt = rand((c, wy, wx), 1), rand((m, c, 3, 3), 2)
    got = conv2d_winograd(img, flt)
    want = ref.conv2d_multi_ref(img, flt)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_winograd_single_channel_operands():
    img, flt = rand((9, 10), 3), rand((3, 3, 3), 4)
    np.testing.assert_allclose(
        conv2d_winograd(img, flt), ref.conv2d_single_ref(img, flt), rtol=RTOL, atol=ATOL)


def test_winograd_rejects_non_k3():
    img, flt = rand((4, 10, 10), 5), rand((2, 4, 5, 5), 6)
    with pytest.raises(ValueError):
        conv2d_winograd(img, flt)


@pytest.mark.parametrize("m_blk,c_seg", [(1, 1), (2, 4), (4, 2)])
def test_winograd_explicit_blocks(m_blk, c_seg):
    img, flt = rand((4, 10, 10), 7), rand((4, 4, 3, 3), 8)
    got = conv2d_winograd(img, flt, m_blk=m_blk, c_seg=c_seg)
    np.testing.assert_allclose(got, ref.conv2d_multi_ref(img, flt), rtol=RTOL, atol=ATOL)


def test_winograd_identity_filter():
    """Center tap of a 3x3 filter = shifted identity — pins the transform
    matrices' orientation."""
    img = rand((1, 8, 8), 9)
    flt = jnp.zeros((1, 1, 3, 3), jnp.float32).at[0, 0, 1, 1].set(1.0)
    got = conv2d_winograd(img, flt)
    np.testing.assert_allclose(got[0], img[0, 1:7, 1:7], rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# FFT convolution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,wy,wx,m,k", [
    (1, 8, 8, 2, 1),
    (4, 12, 12, 6, 3),
    (8, 7, 9, 4, 3),
    (2, 16, 16, 3, 5),
    (6, 11, 13, 2, 7),  # large K relative to the map: FFT's home turf
])
def test_fft_matches_ref(c, wy, wx, m, k):
    img, flt = rand((c, wy, wx), 10), rand((m, c, k, k), 11)
    got = conv2d_fft(img, flt)
    want = ref.conv2d_multi_ref(img, flt)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_fft_single_channel_operands():
    img, flt = rand((10, 10), 12), rand((4, 3, 3), 13)
    np.testing.assert_allclose(
        conv2d_fft(img, flt), ref.conv2d_single_ref(img, flt), rtol=RTOL, atol=ATOL)


def test_fft_is_cross_correlation_not_convolution():
    """An asymmetric filter distinguishes correlation from convolution —
    the conj() in the kernel must implement the paper's eq. (1)."""
    img = rand((1, 6, 6), 14)
    flt = jnp.zeros((1, 1, 3, 3), jnp.float32).at[0, 0, 0, 0].set(1.0)
    got = conv2d_fft(img, flt)
    np.testing.assert_allclose(got[0], img[0, :4, :4], rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# all four families agree
# ---------------------------------------------------------------------------

def test_all_four_families_agree():
    c, wy, wx, m, k = 8, 12, 12, 8, 3
    img, flt = rand((c, wy, wx), 20), rand((m, c, k, k), 21)
    direct = conv2d_multi(img, flt)          # the paper's kernel (direct family)
    gemm = conv2d_im2col(img, flt)           # GEMM family
    wino = conv2d_winograd(img, flt)         # Winograd family
    fft = conv2d_fft(img, flt)               # FFT family
    for other, name in [(gemm, "gemm"), (wino, "winograd"), (fft, "fft")]:
        np.testing.assert_allclose(direct, other, rtol=RTOL, atol=ATOL, err_msg=name)
