"""Kernel vs. reference-oracle correctness — the CORE numeric signal.

Every Pallas kernel must agree with the pure-jnp oracle (ref.py) to
float32 tolerance, across the paper's K values, awkward (non-square,
prime-sized) maps, explicit tile/segment choices, and both dtypes.
The two oracle forms are also cross-checked against each other so a bug
in one cannot silently become the ground truth.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import (
    conv2d_im2col,
    conv2d_multi,
    conv2d_single,
    choose_multi_tiles,
    choose_single_tiles,
    ref,
)

RTOL, ATOL = 1e-4, 1e-4


def rand(shape, seed, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------------------
# Oracle self-consistency
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wy,wx,m,k", [(8, 8, 4, 1), (12, 16, 8, 3), (16, 12, 3, 5), (7, 7, 2, 7)])
def test_single_oracles_agree(wy, wx, m, k):
    img, flt = rand((wy, wx), 1), rand((m, k, k), 2)
    np.testing.assert_allclose(
        ref.conv2d_single_ref(img, flt), ref.conv2d_single_lax(img, flt), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("c,wy,wx,m,k", [(1, 8, 8, 4, 3), (3, 10, 14, 5, 3), (8, 7, 7, 6, 1), (4, 9, 9, 2, 5)])
def test_multi_oracles_agree(c, wy, wx, m, k):
    img, flt = rand((c, wy, wx), 3), rand((m, c, k, k), 4)
    a = ref.conv2d_multi_ref(img, flt)
    np.testing.assert_allclose(a, ref.conv2d_multi_lax(img, flt), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(a, ref.conv2d_multi_im2col_ref(img, flt), rtol=RTOL, atol=ATOL)


def test_single_known_values():
    """Hand-computed 3x3/K=2 case pins the convolution orientation
    (cross-correlation, eq. (2) — not flipped-filter convolution)."""
    img = jnp.arange(9.0, dtype=jnp.float32).reshape(3, 3)
    flt = jnp.array([[[1.0, 0.0], [0.0, 0.0]]])  # identity tap at (0,0)
    out = ref.conv2d_single_ref(img, flt)
    np.testing.assert_allclose(out[0], img[:2, :2])
    flt2 = jnp.array([[[0.0, 0.0], [0.0, 1.0]]])  # tap at (1,1)
    out2 = ref.conv2d_single_ref(img, flt2)
    np.testing.assert_allclose(out2[0], img[1:, 1:])


def test_multi_channel_sum_known_values():
    """C identical channels with all-ones 1x1 filters == C * image."""
    c = 5
    img = jnp.stack([jnp.full((4, 4), 2.0)] * c)
    flt = jnp.ones((1, c, 1, 1), jnp.float32)
    np.testing.assert_allclose(ref.conv2d_multi_ref(img, flt)[0], jnp.full((4, 4), 2.0 * c))


# ---------------------------------------------------------------------------
# Pallas single-channel kernel (§3.1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wy,wx,m,k", [
    (8, 8, 4, 1), (12, 16, 8, 3), (16, 12, 4, 5),
    (28, 28, 16, 3),   # paper's smallest Fig.4 map
    (11, 13, 3, 3),    # prime sizes force degenerate tiling
    (32, 32, 32, 1),
])
def test_pallas_single_matches_ref(wy, wx, m, k):
    img, flt = rand((wy, wx), 10), rand((m, k, k), 11)
    np.testing.assert_allclose(
        conv2d_single(img, flt), ref.conv2d_single_ref(img, flt), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("m_tile,y_tile", [(1, 1), (2, 5), (4, 10), (8, 2), (1, 10)])
def test_pallas_single_explicit_tiles(m_tile, y_tile):
    """Every legal (P, Q) division computes the same result (eq. 5/8)."""
    wy, wx, m, k = 12, 9, 8, 3  # Oy = 10
    img, flt = rand((wy, wx), 12), rand((m, k, k), 13)
    out = conv2d_single(img, flt, m_tile=m_tile, y_tile=y_tile)
    np.testing.assert_allclose(out, ref.conv2d_single_ref(img, flt), rtol=RTOL, atol=ATOL)


def test_pallas_single_rejects_nondividing_tiles():
    img, flt = rand((12, 9), 14), rand((8, 3, 3), 15)
    with pytest.raises(ValueError):
        conv2d_single(img, flt, m_tile=3, y_tile=1)  # 8 % 3 != 0
    with pytest.raises(ValueError):
        conv2d_single(img, flt, m_tile=1, y_tile=4)  # 10 % 4 != 0


def test_choose_single_tiles_feasible():
    for (wy, wx, m, k) in [(28, 28, 512, 1), (1024, 1024, 32, 5), (56, 56, 128, 3)]:
        m_tile, y_tile = choose_single_tiles(wy, wx, m, k)
        oy = wy - k + 1
        assert m % m_tile == 0 and oy % y_tile == 0
        # eq.(5) working set within the block budget
        assert m_tile * y_tile * (wx - k + 1) + (y_tile + k - 1) * wx <= 24 * 1024


# ---------------------------------------------------------------------------
# Pallas multi-channel stride-fixed block kernel (§3.2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,wy,wx,m,k", [
    (1, 8, 8, 4, 3),
    (4, 12, 12, 6, 3),
    (8, 7, 7, 8, 3),    # the deep-layer 7x7 case of Fig. 5
    (16, 14, 14, 8, 1),
    (4, 9, 11, 2, 5),
    (32, 7, 7, 16, 3),
])
def test_pallas_multi_matches_ref(c, wy, wx, m, k):
    img, flt = rand((c, wy, wx), 20), rand((m, c, k, k), 21)
    np.testing.assert_allclose(
        conv2d_multi(img, flt), ref.conv2d_multi_ref(img, flt), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("m_blk,c_seg", [(1, 1), (2, 4), (4, 2), (8, 8), (1, 8)])
def test_pallas_multi_explicit_blocks(m_blk, c_seg):
    """Every legal (S, M') point computes identical results."""
    c, wy, wx, m, k = 8, 10, 10, 8, 3
    img, flt = rand((c, wy, wx), 22), rand((m, c, k, k), 23)
    out = conv2d_multi(img, flt, m_blk=m_blk, c_seg=c_seg)
    np.testing.assert_allclose(out, ref.conv2d_multi_ref(img, flt), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("segment_bytes", [32, 64, 128])
def test_pallas_multi_segment_sizes(segment_bytes):
    """The paper's S ablation points all agree numerically."""
    c, wy, wx, m, k = 16, 8, 8, 4, 1
    img, flt = rand((c, wy, wx), 24), rand((m, c, k, k), 25)
    out = conv2d_multi(img, flt, segment_bytes=segment_bytes)
    np.testing.assert_allclose(out, ref.conv2d_multi_ref(img, flt), rtol=RTOL, atol=ATOL)


def test_pallas_multi_rejects_nondividing_blocks():
    img, flt = rand((6, 8, 8), 26), rand((4, 6, 3, 3), 27)
    with pytest.raises(ValueError):
        conv2d_multi(img, flt, m_blk=3, c_seg=1)
    with pytest.raises(ValueError):
        conv2d_multi(img, flt, m_blk=1, c_seg=4)


def test_choose_multi_tiles_respects_segment():
    # K=1: S=32B -> 8 channels per segment; K=3 taps are 36B > 32 -> 1 ch.
    assert choose_multi_tiles(64, 14, 14, 64, 1, segment_bytes=32)[1] == 8
    assert choose_multi_tiles(64, 14, 14, 64, 3, segment_bytes=32)[1] == 1
    assert choose_multi_tiles(64, 14, 14, 64, 1, segment_bytes=64)[1] == 16


# ---------------------------------------------------------------------------
# Implicit-GEMM baseline kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,wy,wx,m,k", [
    (4, 12, 12, 6, 3), (8, 7, 7, 8, 3), (16, 14, 14, 8, 1), (4, 9, 11, 2, 5),
])
def test_pallas_im2col_matches_ref(c, wy, wx, m, k):
    img, flt = rand((c, wy, wx), 30), rand((m, c, k, k), 31)
    np.testing.assert_allclose(
        conv2d_im2col(img, flt), ref.conv2d_multi_ref(img, flt), rtol=RTOL, atol=ATOL)


def test_im2col_accepts_single_channel_operands():
    img, flt = rand((10, 10), 32), rand((4, 3, 3), 33)
    np.testing.assert_allclose(
        conv2d_im2col(img, flt), ref.conv2d_single_ref(img, flt), rtol=RTOL, atol=ATOL)


def test_kernels_agree_with_each_other():
    """stride-fixed vs implicit-GEMM on the same operands (the comparison
    the rust integration test repeats through PJRT)."""
    c, wy, wx, m, k = 32, 14, 14, 32, 3
    img, flt = rand((c, wy, wx), 34), rand((m, c, k, k), 35)
    np.testing.assert_allclose(
        conv2d_multi(img, flt), conv2d_im2col(img, flt), rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# dtypes
# ---------------------------------------------------------------------------

def test_single_bfloat16():
    img = rand((12, 12), 40).astype(jnp.bfloat16)
    flt = rand((4, 3, 3), 41).astype(jnp.bfloat16)
    out = conv2d_single(img, flt)
    assert out.dtype == jnp.bfloat16
    want = ref.conv2d_single_ref(img.astype(jnp.float32), flt.astype(jnp.float32))
    np.testing.assert_allclose(out.astype(jnp.float32), want, rtol=5e-2, atol=5e-2)


def test_multi_bfloat16():
    img = rand((4, 10, 10), 42).astype(jnp.bfloat16)
    flt = rand((4, 4, 3, 3), 43).astype(jnp.bfloat16)
    out = conv2d_multi(img, flt)
    assert out.dtype == jnp.bfloat16
    want = ref.conv2d_multi_ref(img.astype(jnp.float32), flt.astype(jnp.float32))
    np.testing.assert_allclose(out.astype(jnp.float32), want, rtol=5e-2, atol=5e-1)
