"""Mirror of rust/src/conv/suites.rs: the paper's workload suites."""

from plans import ConvProblem

PAPER_KS = [1, 3, 5]

FIG4_POINTS = [(28, 512), (56, 256), (112, 128), (224, 64), (512, 32), (1024, 32)]

FIG5_POINTS = [(7, 512), (14, 256), (28, 128), (56, 128), (112, 64), (224, 64), (512, 64)]


def fig4_suite():
    return [ConvProblem.single(w, m, k) for k in PAPER_KS for (w, m) in FIG4_POINTS]


def fig5_suite():
    return [ConvProblem.multi(c, w, c, k) for k in PAPER_KS for (w, c) in FIG5_POINTS]


def alexnet():
    return [ConvProblem.multi(96, 27, 256, 5), ConvProblem.multi(256, 13, 384, 3),
            ConvProblem.multi(384, 13, 384, 3), ConvProblem.multi(384, 13, 256, 3)]


def vgg16():
    return [ConvProblem.multi(3, 224, 64, 3), ConvProblem.multi(64, 224, 64, 3),
            ConvProblem.multi(64, 112, 128, 3), ConvProblem.multi(128, 112, 128, 3),
            ConvProblem.multi(128, 56, 256, 3), ConvProblem.multi(256, 56, 256, 3),
            ConvProblem.multi(256, 28, 512, 3), ConvProblem.multi(512, 28, 512, 3),
            ConvProblem.multi(512, 14, 512, 3)]


def resnet18():
    return [ConvProblem.multi(64, 56, 64, 3), ConvProblem.multi(64, 28, 128, 3),
            ConvProblem.multi(64, 28, 128, 1), ConvProblem.multi(128, 28, 128, 3),
            ConvProblem.multi(128, 14, 256, 3), ConvProblem.multi(128, 14, 256, 1),
            ConvProblem.multi(256, 14, 256, 3), ConvProblem.multi(256, 7, 512, 3),
            ConvProblem.multi(256, 7, 512, 1), ConvProblem.multi(512, 7, 512, 3)]


def googlenet_inception3a():
    return [ConvProblem.multi(192, 28, 64, 1),
            ConvProblem.multi(192, 28, 96, 1), ConvProblem.multi(96, 28, 128, 3),
            ConvProblem.multi(192, 28, 16, 1), ConvProblem.multi(16, 28, 32, 5),
            ConvProblem.multi(192, 28, 32, 1)]


def all_cnn_layers():
    out = []
    for p in alexnet() + vgg16() + resnet18() + googlenet_inception3a():
        if p not in out:
            out.append(p)
    return out
