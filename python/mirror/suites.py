"""Mirror of rust/src/conv/suites.rs: the paper's figure suites
(ConvProblem) and the op-level model suites (ConvOp) — real 'same'
padding, ResNet-18's native stride-2 downsampling, MobileNetV1's
depthwise-separable stack."""

from ops import ConvOp
from plans import ConvProblem

PAPER_KS = [1, 3, 5]

FIG4_POINTS = [(28, 512), (56, 256), (112, 128), (224, 64), (512, 32), (1024, 32)]

FIG5_POINTS = [(7, 512), (14, 256), (28, 128), (56, 128), (112, 64), (224, 64), (512, 64)]


def fig4_suite():
    return [ConvProblem.single(w, m, k) for k in PAPER_KS for (w, m) in FIG4_POINTS]


def fig5_suite():
    return [ConvProblem.multi(c, w, c, k) for k in PAPER_KS for (w, c) in FIG5_POINTS]


def alexnet():
    return [ConvOp.same(ConvProblem.multi(96, 27, 256, 5)),
            ConvOp.same(ConvProblem.multi(256, 13, 384, 3)),
            ConvOp.same(ConvProblem.multi(384, 13, 384, 3)),
            ConvOp.same(ConvProblem.multi(384, 13, 256, 3))]


def vgg16():
    return [ConvOp.same(ConvProblem.multi(3, 224, 64, 3)),
            ConvOp.same(ConvProblem.multi(64, 224, 64, 3)),
            ConvOp.same(ConvProblem.multi(64, 112, 128, 3)),
            ConvOp.same(ConvProblem.multi(128, 112, 128, 3)),
            ConvOp.same(ConvProblem.multi(128, 56, 256, 3)),
            ConvOp.same(ConvProblem.multi(256, 56, 256, 3)),
            ConvOp.same(ConvProblem.multi(256, 28, 512, 3)),
            ConvOp.same(ConvProblem.multi(512, 28, 512, 3)),
            ConvOp.same(ConvProblem.multi(512, 14, 512, 3))]


def resnet18():
    return [ConvOp.same(ConvProblem.multi(64, 56, 64, 3)),
            ConvOp.strided(ConvProblem.multi(64, 56, 128, 3), 2, 1),
            ConvOp.strided(ConvProblem.multi(64, 56, 128, 1), 2, 0),
            ConvOp.same(ConvProblem.multi(128, 28, 128, 3)),
            ConvOp.strided(ConvProblem.multi(128, 28, 256, 3), 2, 1),
            ConvOp.strided(ConvProblem.multi(128, 28, 256, 1), 2, 0),
            ConvOp.same(ConvProblem.multi(256, 14, 256, 3)),
            ConvOp.strided(ConvProblem.multi(256, 14, 512, 3), 2, 1),
            ConvOp.strided(ConvProblem.multi(256, 14, 512, 1), 2, 0),
            ConvOp.same(ConvProblem.multi(512, 7, 512, 3))]


def googlenet_inception3a():
    return [ConvOp.dense(ConvProblem.multi(192, 28, 64, 1)),
            ConvOp.dense(ConvProblem.multi(192, 28, 96, 1)),
            ConvOp.same(ConvProblem.multi(96, 28, 128, 3)),
            ConvOp.dense(ConvProblem.multi(192, 28, 16, 1)),
            ConvOp.same(ConvProblem.multi(16, 28, 32, 5)),
            ConvOp.dense(ConvProblem.multi(192, 28, 32, 1))]


MOBILENET_BLOCKS = [(32, 1, 64), (64, 2, 128), (128, 1, 128), (128, 2, 256),
                    (256, 1, 256), (256, 2, 512), (512, 1, 512), (512, 1, 512),
                    (512, 1, 512), (512, 1, 512), (512, 1, 512), (512, 2, 1024),
                    (1024, 1, 1024)]


def mobilenet_v1():
    out = [ConvOp.strided(ConvProblem.multi(3, 224, 32, 3), 2, 1)]
    w = 112
    for (c_in, stride, c_out) in MOBILENET_BLOCKS:
        out.append(ConvOp.depthwise(c_in, w, 3, stride))
        w //= stride
        out.append(ConvOp.pointwise(c_in, w, c_out))
    return out


def model_ops():
    return [("alexnet", alexnet()), ("vgg16", vgg16()), ("resnet18", resnet18()),
            ("inception3a", googlenet_inception3a()),
            ("mobilenet_v1", mobilenet_v1())]


def all_cnn_ops():
    out = []
    for (_, ops) in model_ops():
        for op in ops:
            if op not in out:
                out.append(op)
    return out


def all_cnn_layers():
    """Deduped lowered units of the four paper-era models (mirror of
    suites::all_cnn_layers)."""
    out = []
    for op in alexnet() + vgg16() + resnet18() + googlenet_inception3a():
        u = op.unit()
        if u not in out:
            out.append(u)
    return out
