"""Mirror of rust/src/conv/op.rs + the backend op layer
(rust/src/backend/{mod,impls,dispatch}.rs): ConvOp with its exact
lowering onto the stride-1/valid/dense regime, the paper backends'
native op schedules (decimated strips for stride, side-by-side groups),
the generic lowered route every other backend serves, and the op
dispatcher with its naive-lowered paper-tuned floor."""

import dataclasses
from dataclasses import dataclass

import backends
import tuner
from gpusim import (EP_ADD, EP_NONE, EP_RELU, ep_pooled_hw, load_cycles,
                    round_without_filter_loads, simulate_cycles,
                    simulate_pipeline_runs, writeback_tail_cycles)
from plans import (BYTES_F32, ConvProblem, multi_choice, single_choice,
                   single_recipe, stage_bytes_multi, stride_recipe)


@dataclass(frozen=True)
class ConvOp:
    core: ConvProblem
    stride: int = 1
    pad: int = 0
    groups: int = 1

    @staticmethod
    def dense(core):
        return ConvOp(core, 1, 0, 1)

    @staticmethod
    def same(core):
        assert core.k % 2 == 1
        return ConvOp(core, 1, (core.k - 1) // 2, 1)

    @staticmethod
    def strided(core, stride, pad):
        return ConvOp(core, stride, pad, 1)

    @staticmethod
    def depthwise(c, w, k, stride):
        assert k % 2 == 1
        return ConvOp(ConvProblem.multi(c, w, c, k), stride, (k - 1) // 2, c)

    @staticmethod
    def pointwise(c, w, m):
        return ConvOp.dense(ConvProblem.multi(c, w, m, 1))

    def is_dense(self):
        return self.stride == 1 and self.pad == 0 and self.groups == 1

    def is_depthwise(self):
        return (self.groups > 1 and self.groups == self.core.c
                and self.groups == self.core.m)

    def padded_wy(self):
        return self.core.wy + 2 * self.pad

    def padded_wx(self):
        return self.core.wx + 2 * self.pad

    def oy(self):
        return (self.padded_wy() - self.core.k) // self.stride + 1

    def ox(self):
        return (self.padded_wx() - self.core.k) // self.stride + 1

    def valid(self):
        p = self.core
        return (p.c >= 1 and p.m >= 1 and p.k >= 1 and p.wy >= 1 and p.wx >= 1
                and self.stride >= 1 and self.groups >= 1
                and p.c % self.groups == 0 and p.m % self.groups == 0
                and self.pad < p.k
                and self.padded_wy() >= p.k and self.padded_wx() >= p.k)

    def map_elems(self):
        return self.core.map_elems()

    def filter_elems(self):
        return self.core.m * (self.core.c // self.groups) * self.core.k * self.core.k

    def out_elems(self):
        return self.core.m * self.oy() * self.ox()

    def unit(self):
        """The lowered per-group stride-1 valid dense problem."""
        return ConvProblem(self.core.c // self.groups, self.padded_wy(),
                           self.padded_wx(), self.core.m // self.groups,
                           self.core.k)

    def output_keep_fraction(self):
        u = self.unit()
        return (self.oy() * self.ox()) / (u.oy() * u.ox())

    def label(self):
        if self.is_dense():
            return self.core.label()
        s = self.core.label()
        if self.stride > 1:
            s += f" s{self.stride}"
        if self.pad > 0:
            s += f" p{self.pad}"
        if self.groups > 1:
            s += " dw" if self.is_depthwise() else f" g{self.groups}"
        return s


# ---- op-native tuning (mirror of tuner::{score_op, build_op_plan,
# tune_op, tuned_op, tuned_op_plan}) ----

def op_objective(op, ep, n):
    """Mirror of OpObjective::for_op: (keep, groups, n, ep, out_hw)."""
    assert n >= 1
    return (op.output_keep_fraction(), op.groups, n, ep,
            (op.oy(), op.ox()))


def score_op(unit, spec, params, obj):
    """Mirror of tuner::score::score_op — exact simulated cycles of a
    unit candidate pushed through the op transforms (decimated, grouped,
    fused, batched with cross-image filter residency where it
    qualifies), in runs form."""
    keep, groups, n, ep, out_hw = obj
    if params[0] == "single":
        _, method, pp, q, st, ld = params
        c = single_choice(unit, spec, method, pp, q)
        first, tail, sms, threads, smem_b, stage_b, resident = \
            single_recipe(unit, spec, c)
        runs = [(first, 1)]
        if tail is not None:
            runs.append(tail)
        smem_staged = min(smem_b, spec.shared_mem_bytes) + (st - 2) * stage_b
        l2_fp = unit.m * unit.k * unit.k * BYTES_F32
    else:
        _, s, wx, mp, st, ld = params
        c = multi_choice(unit, spec, s, wx, mp)
        rnd, count, sms, threads, resident = stride_recipe(unit, spec, c)
        runs = [(rnd, count)]
        smem_staged = c.smem_bytes + (st - 2) * stage_bytes_multi(
            s, wx, mp, unit.k)
        l2_fp = unit.m * unit.c * unit.k * unit.k * BYTES_F32
    # decimation: only the kept rows' FMAs are charged, loads stay
    runs = [(dataclasses.replace(r, fma_ops=r.fma_ops * keep), cnt)
            for (r, cnt) in runs]
    # grouping: par groups side by side, the rest as sequential waves
    par = min(max(spec.sm_count // sms, 1), groups)
    waves = (groups + par - 1) // par
    sms_g = sms * par
    per_image = sum(cnt for _, cnt in runs) * waves
    if per_image * n > tuner.MAX_ROUNDS:
        return None
    image_runs = list(runs) * waves
    # epilogue pricing against the op-level output map
    out = unit.out_elems() * BYTES_F32 * keep * groups
    ep_read = 0.0
    if ep in (EP_NONE, EP_RELU):
        pass
    elif ep == EP_ADD:
        ep_read = out
    else:
        oy, ox = out_hw
        py, px = ep_pooled_hw(ep, oy, ox)
        out *= (py * px) / (oy * ox)
    cfg = tuner._exec_config(sms_g, threads, st, ld)
    # cross-image filter residency: the capacity and warm-vs-cold guards
    # of KernelPlan::batched_resident, in recipe form (the grouped plan
    # pins every wave's filters in smem, hence resident x waves; the L2
    # tier must hold every group's filter tensor, hence footprint x
    # groups)
    resident_g = resident * waves
    l2_fp_g = l2_fp * groups
    fits = ((resident_g > 0
             and smem_staged + resident_g <= spec.shared_mem_bytes)
            or (l2_fp_g > 0 and l2_fp_g <= spec.l2_resident_budget()))
    qualify = (n > 1 and fits
               and all(load_cycles(spec, cfg, round_without_filter_loads(r))
                       <= load_cycles(spec, cfg, r) + 1e-9
                       for (r, _) in image_runs))
    all_runs = list(image_runs)
    for _ in range(1, n):
        if qualify:
            all_runs.extend((round_without_filter_loads(r), cnt)
                            for (r, cnt) in image_runs)
        else:
            all_runs.extend(image_runs)
    t, _ = simulate_pipeline_runs(spec, cfg, all_runs)
    loads = sum(r.load_bytes * cnt for (r, cnt) in all_runs) * sms_g
    out_total = out * n
    ep_total = ep_read * n
    tail_c = writeback_tail_cycles(spec, out_total + ep_total, st)
    floor = (loads + out_total + ep_total) / spec.bytes_per_cycle()
    return t + max(tail_c, floor - t)


def build_op_plan(op, ep, n, spec, params):
    """Mirror of tuner::build_op_plan: the unit plan for `params` pushed
    through the serving transforms, native vs lowered priced and the
    faster kept."""
    assert op.valid() and n >= 1
    unit = tuner.build_plan(op.unit(), spec, params)

    def finish(pl):
        return pl.fused(ep, (op.oy(), op.ox())).batched_resident(n, spec)

    native_base = unit.decimated(op.output_keep_fraction()).grouped(
        op.groups, spec.sm_count)
    native_base = _rename(native_base, op_plan_name(unit.name, op, True))
    native = finish(native_base)
    if op.groups == 1 and op.output_keep_fraction() == 1.0:
        return native  # dense: the lowering IS the native route
    lowered_base = _rename(unit.batched(op.groups),
                           op_plan_name(unit.name, op, False))
    lowered = finish(lowered_base)
    if simulate_cycles(spec, native) <= simulate_cycles(spec, lowered):
        return native
    return lowered


def tune_op(op, ep, n, spec):
    """Mirror of tuner::tune_op: direct search over the unit plan space
    under the op-level objective, seeded (never-lose) by the inherited-
    geometry plan.  Returns (tuned_cycles, params, inherited_cycles)."""
    assert op.valid() and n >= 1
    inherited = tuner.tuned_params(op.unit(), spec)
    inherited_cycles = simulate_cycles(
        spec, build_op_plan(op, ep, n, spec, inherited))
    obj = op_objective(op, ep, n)
    scored = []
    for cand in tuner.enumerate_params(op.unit(), spec):
        s = score_op(op.unit(), spec, cand, obj)
        if s is not None:
            scored.append((s, cand))
    scored.sort(key=lambda x: x[0])
    best = (inherited_cycles, inherited)
    checked = 0
    for _, params in scored:
        if checked == tuner.TOP_K:
            break
        plan = build_op_plan(op, ep, n, spec, params)
        if not tuner.is_legal(spec, plan):
            continue
        checked += 1
        cycles = simulate_cycles(spec, plan)
        if cycles < best[0]:
            best = (cycles, params)
    return best[0], best[1], inherited_cycles


_OPTUNE_CACHE = {}


def tuned_op(op, ep, n, spec):
    key = (op, ep, n, spec.name)
    if key not in _OPTUNE_CACHE:
        _OPTUNE_CACHE[key] = tune_op(op, ep, n, spec)
    return _OPTUNE_CACHE[key]


def tuned_op_plan(op, ep, n, spec):
    return build_op_plan(op, ep, n, spec, tuned_op(op, ep, n, spec)[1])


# ---- op plans (mirror of ConvBackend::op_plan + impls::paper_op_plan) ----

def op_plan_name(unit_name, op, native):
    s = unit_name
    if op.groups > 1:
        s += f" g{op.groups}"
    if op.stride > 1:
        s += f" s{op.stride}"
    if not native and not op.is_dense():
        s += " lowered"
    return s


def _rename(plan, name):
    plan2 = type(plan)(**{**plan.__dict__, "name": name})
    return plan2


def lowered_plan(unit_plan_fn, op, spec):
    """The naive lowered schedule: unit plan batched over the groups,
    full stride-1 output.  Dense ops are just the unit plan."""
    if op.is_dense():
        return unit_plan_fn(op.core, spec)
    unit = unit_plan_fn(op.unit(), spec)
    return _rename(unit.batched(op.groups), op_plan_name(unit.name, op, False))


def paper_op_plan(unit_plan_fn, op, spec):
    """Mirror of impls::paper_op_plan: min(native, lowered) under the
    simulator — the native route decimates the strip schedule and runs
    groups side by side on idle SMs."""
    if op.is_dense():
        return unit_plan_fn(op.core, spec)
    unit = unit_plan_fn(op.unit(), spec)
    native = _rename(
        unit.decimated(op.output_keep_fraction()).grouped(op.groups, spec.sm_count),
        op_plan_name(unit.name, op, True))
    lowered = _rename(unit.batched(op.groups), op_plan_name(unit.name, op, False))
    if simulate_cycles(spec, native) <= simulate_cycles(spec, lowered):
        return native
    return lowered


def op_coverage(name, supports, op):
    """Mirror of the trait's op_coverage: paper backends are native on
    every valid op; others are native on dense / lowered via the unit."""
    if not op.valid():
        return None
    if name in ("paper-tuned", "paper"):
        return "native"
    if op.is_dense():
        return "native" if supports(op.core) else None
    return "lowered" if supports(op.unit()) else None


def backend_op_plan(name, op, spec):
    if name == "paper-tuned":
        # mirror of impls::PaperTuned::op_plan — non-dense ops go
        # through the OP-NATIVE tuner, never-lose vs the old
        # paper_op_plan route by seeding
        if op.is_dense():
            return tuner.tuned_plan(op.core, spec)
        return tuned_op_plan(op, EP_NONE, 1, spec)
    if name == "paper":
        from plans import paper_plan_for
        return paper_op_plan(paper_plan_for, op, spec)
    for (n, _, planfn) in backends.NON_TUNED_BACKENDS:
        if n == name:
            return lowered_plan(planfn, op, spec)
    raise KeyError(name)


def batched_backend_op_plan(name, op, n, spec):
    """Mirror of ConvBackend::batched_op_plan: paper-tuned re-tunes
    under the batch-n objective (filter residency priced); every other
    backend batches its op plan."""
    if name == "paper-tuned":
        if n == 1:
            return backend_op_plan(name, op, spec)
        return tuned_op_plan(op, EP_NONE, n, spec)
    return backend_op_plan(name, op, spec).batched(n)


def _decide_op_n(op, n, spec):
    """Mirror of Dispatcher::decide_op_n: floor = the paper-tuned NAIVE
    lowering; paper-tuned serves min(native, lowered); every covering
    backend's op plan ranked on its batch-n schedule behind the
    legality gate."""
    assert op.valid()
    floor = lowered_plan(tuner.tuned_plan, op, spec)
    tuned_cycles = simulate_cycles(spec, floor.batched(n))
    # paper-tuned is ranked on its batched OP plan — op-native tuned,
    # with cross-image filter residency where it qualifies
    best = (backends.PAPER_TUNED,
            simulate_cycles(spec, batched_backend_op_plan("paper-tuned", op, n, spec)))
    for (name, supports, planfn) in backends.NON_TUNED_BACKENDS:
        if op_coverage(name, supports, op) is None:
            continue
        plan = lowered_plan(planfn, op, spec) if name != "paper" \
            else backend_op_plan("paper", op, spec)
        if not tuner.is_legal(spec, plan):
            continue
        cycles = simulate_cycles(spec, plan.batched(n))
        if cycles < best[1]:
            best = (name, cycles)
    return (best[0], best[1], tuned_cycles)


_OP_CACHE = {}


def decide_op(op, spec):
    key = (op, spec.name)
    if key not in _OP_CACHE:
        _OP_CACHE[key] = _decide_op_n(op, 1, spec)
    return _OP_CACHE[key]


_OP_BATCHED_CACHE = {}


def decide_batched_op(op, n, spec):
    if n == 1:
        return decide_op(op, spec)
    key = (op, n, spec.name)
    if key not in _OP_BATCHED_CACHE:
        _OP_BATCHED_CACHE[key] = _decide_op_n(op, n, spec)
    return _OP_BATCHED_CACHE[key]


def batched_op_dispatch_seconds(op, n, spec):
    """Mirror of backend::batched_op_dispatch_seconds — the fleet's
    per-shard job pricing."""
    return spec.cycles_to_secs(decide_batched_op(op, n, spec)[1])


def footprint_bytes(op, n):
    """Mirror of BatchedConvOp::footprint_bytes: the device bytes an
    n-image batch pins while resident on a shard — batched inputs +
    filters + batched outputs at f32, rounded up to the pool's 256 B
    class lattice."""
    nbytes = (n * op.map_elems() + op.filter_elems() + n * op.out_elems()) * 4
    return (nbytes + 255) // 256 * 256


def dispatch_op_plan(op, spec):
    name, _, _ = decide_op(op, spec)
    return backend_op_plan(name, op, spec)


# ---- fused dispatch (mirror of Dispatcher::decide_fused_op) ----

def fused_backend_op_plan(name, op, ep, spec):
    """Mirror of ConvBackend::fused_op_plan: paper-tuned RE-TUNES over
    the epilogue axis (impls::PaperTuned::fused_op_plan); every other
    backend folds the epilogue into its op plan's writeback tail."""
    if name == "paper-tuned":
        if ep == EP_NONE:
            return backend_op_plan(name, op, spec)
        return tuned_op_plan(op, ep, 1, spec)
    return backend_op_plan(name, op, spec).fused(ep, (op.oy(), op.ox()))


def _decide_fused_op(op, ep, spec):
    """Mirror of Dispatcher::decide_fused_op: same ranking as decide_op
    with every candidate's plan carrying ep, floored by the paper-tuned
    naive lowered schedule fused the same way."""
    assert op.valid()
    out_hw = (op.oy(), op.ox())
    tuned_cycles = simulate_cycles(
        spec, lowered_plan(tuner.tuned_plan, op, spec).fused(ep, out_hw))
    # paper-tuned's native-vs-lowered memo was decided on UNFUSED
    # cycles; min against the fused floor keeps cycles <= tuned_cycles
    seed = min(simulate_cycles(spec, fused_backend_op_plan("paper-tuned", op, ep, spec)),
               tuned_cycles)
    best = (backends.PAPER_TUNED, seed)
    for (name, supports, _planfn) in backends.NON_TUNED_BACKENDS:
        if op_coverage(name, supports, op) is None:
            continue
        plan = fused_backend_op_plan(name, op, ep, spec)
        if not tuner.is_legal(spec, plan):
            continue
        cycles = simulate_cycles(spec, plan)
        if cycles < best[1]:
            best = (name, cycles)
    return (best[0], best[1], tuned_cycles)


_FUSED_OP_CACHE = {}


def decide_fused_op(op, ep, spec):
    if ep == EP_NONE:
        return decide_op(op, spec)
    key = (op, ep, spec.name)
    if key not in _FUSED_OP_CACHE:
        _FUSED_OP_CACHE[key] = _decide_fused_op(op, ep, spec)
    return _FUSED_OP_CACHE[key]


def dispatch_fused_op_plan(op, ep, spec):
    """Mirror of backend::dispatch_fused_op_plan — what the graph
    fusion pass serves for a conv node that absorbed its consumer."""
    if ep == EP_NONE:
        return dispatch_op_plan(op, spec)
    name, _, _ = decide_fused_op(op, ep, spec)
    return fused_backend_op_plan(name, op, ep, spec)


def op_plan_for(op, spec, ep=EP_NONE):
    """Mirror of plans::op_plan_for (the op-native tuned paper path —
    fused plans are re-searched under the fused objective)."""
    return fused_backend_op_plan("paper-tuned", op, ep, spec)


def paper_op_plan_for(op, spec, ep=EP_NONE):
    """Mirror of plans::paper_op_plan_for (§3 closed forms)."""
    plan = backend_op_plan("paper", op, spec)
    return plan if ep == EP_NONE else plan.fused(ep, (op.oy(), op.ox()))
