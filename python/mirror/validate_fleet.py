"""Validate the fleet layer's numbers and invariants, and generate the
EXPERIMENTS.md §8 and §11 tables, by replaying rust/benches/e2e_fleet.rs
exactly (same xoshiro stream, same cost model, same scheduler and pool
arithmetic).

Run: python3 python/mirror/validate_fleet.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))

import graph as graphmod
import ops
import suites
import tuner
from fleet import Fleet, LEAST_LOADED, LEAST_LOADED_BYTES, MODEL_AFFINITY, \
    ROUND_ROBIN
from gpusim import gtx_1080ti, titan_x_maxwell
from ops import ConvOp
from plans import ConvProblem
from pool import DevicePool, PoolExhausted
from rng import Rng

F64_MIN_POSITIVE = 2.2250738585072014e-308  # rust f64::MIN_POSITIVE


def model_layers():
    # mirror of fleet/traffic.rs::model_layers — real op geometry,
    # MobileNetV1 included
    return [("alexnet", suites.alexnet()), ("resnet18", suites.resnet18()),
            ("vgg16", suites.vgg16()), ("mobilenet_v1", suites.mobilenet_v1())]


def offered_load(n, rate, seed, batch=None):
    # mirror of rust/src/fleet/traffic.rs::offered_load (batch=None draws
    # {1,2,4,8} per request; a fixed batch skips that draw)
    import math
    models = model_layers()
    rng = Rng(seed)
    t = 0.0
    out = []
    for _ in range(n):
        u = max(rng.next_f64(), F64_MIN_POSITIVE)
        t += -math.log(u) / rate
        model, layers = models[rng.range_usize(0, len(models) - 1)]
        op = rng.choose(layers)
        b = batch if batch is not None else [1, 2, 4, 8][rng.range_usize(0, 3)]
        out.append((t, op, b, model))
    return out


def run(specs, policy, queue_bound, load, capacity_bytes=None):
    f = Fleet(specs, policy, queue_bound, capacity_bytes)
    completions = []
    for (t, problem, batch, model) in load:
        completions.extend(f.complete_until(t))
        f.submit(problem, batch, model)
    completions.extend(f.drain())
    ids = {c.job for c in completions}
    assert len(ids) == len(completions), "duplicate completion"
    assert len(completions) == f.accepted, "lost job"
    makespan = max((c.finish for c in completions), default=0.0)
    lats = sorted(c.latency() for c in completions)

    def pct(q):
        # mirror util::stats::percentile_sorted: nearest-rank, p in [0,100]
        if not lats:
            return 0.0
        rank = int(round(q / 100.0 * (len(lats) - 1.0)))
        return lats[min(rank, len(lats) - 1)]

    utils = [d.busy_secs / makespan for d in f.devices] if makespan else [0.0]
    pool_peak = 0
    for d in f.devices:
        # the invariants every run re-checks on the real load: the cap
        # held at the high-water mark and the drain released everything
        assert d.pool.peak_in_use_slab <= d.pool.capacity, \
            f"pool cap burst on device {d.id}"
        assert d.pool.in_use_slab_bytes() == 0, \
            f"drain left bytes resident on device {d.id}"
        pool_peak = max(pool_peak, d.pool.peak_in_use_slab)
    return {
        "accepted": f.accepted, "rejected": f.rejected,
        "completed": len(completions),
        "throughput": len(completions) / makespan if makespan else 0.0,
        "makespan": makespan, "p50": pct(50.0), "p99": pct(99.0),
        "spills": f.affinity_spills,
        "umin": min(utils), "umax": max(utils),
        "mem_rejected": f.mem_rejected, "pool_peak": pool_peak,
    }


def check(cond, msg):
    if not cond:
        print(f"FAIL: {msg}")
        sys.exit(1)
    print(f"ok: {msg}")


def main():
    g = gtx_1080ti()
    tx = titan_x_maxwell()

    # ---- invariants: batched cost model ----
    templates = [ConvProblem.multi(8, 14, 16, 3), ConvProblem.single(32, 16, 3),
                 ConvProblem.multi(16, 7, 32, 3)]
    for p in templates:
        single = tuner.batched_cycles(p, 1, g)
        last = 0.0
        for n in range(1, 9):
            c = tuner.batched_cycles(p, n, g)
            check(c > last, f"{p.label()}: cycles monotone at n={n}")
            check(c <= n * single * (1 + 1e-9), f"{p.label()}: amortizes at n={n}")
            last = c
    # fleet makespan floor/ceiling on identical jobs
    op_templates = [ConvOp.dense(p) for p in templates]
    op_templates.append(ConvOp.strided(ConvProblem.multi(8, 28, 16, 3), 2, 1))
    op_templates.append(ConvOp.depthwise(16, 14, 3, 1))
    for t in op_templates:
        single = ops.batched_op_dispatch_seconds(t, 1, g)
        last = 0.0
        for n in range(1, 9):
            c = ops.batched_op_dispatch_seconds(t, n, g)
            check(last < c <= n * single * (1 + 1e-9),
                  f"{t.label()}: op dispatch monotone+amortizing at n={n}")
            last = c
    for d in (1, 2, 4, 8):
        f = Fleet([g] * d, LEAST_LOADED, 64)
        single = f.predicted_service(op_templates[0], 1, 0)
        for _ in range(24):
            assert f.submit(op_templates[0], 1) is not None
        makespan = max(c.finish for c in f.drain())
        floor = 24 / d * single
        import math
        ceiling = math.ceil(24 / d) * single
        check(floor * (1 - 1e-9) <= makespan <= ceiling * (1 + 1e-9),
              f"{d} devices: makespan {makespan:.6f} within [n/D floor, ceil]")

    # ---- e2e_fleet replay ----
    # capacity probe priced like the fleet prices: dispatched per spec
    n = 512
    probe = offered_load(256, 1.0, 0xF1EE7)
    mean_service = sum(ops.batched_op_dispatch_seconds(o, b, g)
                       for (_, o, b, _) in probe) / len(probe)
    rate = 6.0 / mean_service
    load = offered_load(n, rate, 0xF1EE7)
    print(f"\noffered rate {rate:.0f} req/s (6x one 1080Ti), {n} requests")

    rows = []
    r1 = run([g], LEAST_LOADED, n, load)
    base = r1["throughput"]
    rows.append(("1", "1080Ti", "least-loaded", r1))
    results = [(1, r1)]
    for d in (2, 4, 8):
        r = run([g] * d, LEAST_LOADED, n, load)
        rows.append((str(d), "1080Ti", "least-loaded", r))
        results.append((d, r))
    rr4 = run([g] * 4, ROUND_ROBIN, n, load)
    rows.append(("4", "1080Ti", "round-robin", rr4))
    af4 = run([g] * 4, MODEL_AFFINITY, n, load)
    rows.append(("4", "1080Ti", "model-affinity", af4))
    af4b = run([g] * 4, MODEL_AFFINITY, 8, load)
    rows.append(("4 (bound 8)", "1080Ti", "model-affinity", af4b))
    het_ll = run([g, g, tx, tx], LEAST_LOADED, n, load)
    rows.append(("4", "2xPascal+2xMaxwell", "least-loaded", het_ll))
    het_rr = run([g, g, tx, tx], ROUND_ROBIN, n, load)
    rows.append(("4", "2xPascal+2xMaxwell", "round-robin", het_rr))

    print("\n| devices | fleet | policy | req/s | p50 lat | p99 lat | util | speedup |")
    print("|---|---|---|---|---|---|---|---|")
    for (d, fl, pol, r) in rows:
        print(f"| {d} | {fl} | {pol} | {r['throughput']:.0f} "
              f"| {r['p50']*1e3:.2f} ms | {r['p99']*1e3:.2f} ms "
              f"| {r['umin']*100:.0f}-{r['umax']*100:.0f}% "
              f"| {r['throughput']/base:.2f}x |")

    bounded = run([g] * 2, LEAST_LOADED, 8, load)
    print(f"\nadmission (2 devices, bound 8): accepted {bounded['accepted']} "
          f"rejected {bounded['rejected']} "
          f"({100*bounded['rejected']/n:.0f}% shed), p99 {bounded['p99']*1e3:.2f} ms")

    # ---- invariants: pooled execution vs the arena planner ----
    # mirror of rust/tests/pool_difftests.rs: per-tensor pooling sits
    # exactly on the liveness floor, never above the arena peak, on all
    # five registered models sharing ONE pool sized for the worst arena
    worst_arena = 0
    per_model = []
    for (mname, build) in graphmod.MODEL_GRAPHS:
        peak, naive, floor = graphmod.plan_arena(build())
        per_model.append((mname, peak, naive, floor))
        worst_arena = max(worst_arena, peak)
    shared = DevicePool(worst_arena)
    for (mname, arena_peak, naive, floor) in per_model:
        p = graphmod.plan_pooled(dict(graphmod.MODEL_GRAPHS)[mname](), shared)
        check(p["peak"] == floor and p["peak"] <= arena_peak,
              f"{mname}: pooled peak {p['peak']} == floor, <= arena {arena_peak}")
        check(p["naive"] == naive and shared.in_use_slab_bytes() == 0,
              f"{mname}: naive bytes agree, pool drained")
    check(shared.evict_free() > 0 and shared.slab_bytes() == 0,
          "trim reclaims every parked byte of the shared pool")
    tiny = DevicePool(1 << 20)
    try:
        graphmod.plan_pooled(dict(graphmod.MODEL_GRAPHS)["vgg16"](), tiny)
        check(False, "vgg16 must exhaust a 1 MiB pool")
    except PoolExhausted:
        check(tiny.live_allocs() == 0 and tiny.in_use_slab_bytes() == 0,
              "exhaustion rolls back cleanly (no poisoned pool)")

    # ---- multi-tenant capped pools (EXPERIMENTS §11) ----
    # mirror of the e2e_fleet bench's capped runs: same offered load,
    # 4 devices, pools capped in units of the largest job footprint.
    # Queue bound 64 so memory — not queue slots — is the binding
    # constraint: every rejection here is a memory rejection.
    max_fp = max(ops.footprint_bytes(o, b) for (_, o, b, _) in load)
    tight = run([g] * 4, LEAST_LOADED, 64, load, 2 * max_fp)
    roomy = run([g] * 4, LEAST_LOADED, 64, load, 5 * max_fp)
    tight_bytes = run([g] * 4, LEAST_LOADED_BYTES, 64, load, 2 * max_fp)
    print(f"\nmulti-tenant pools (4 devices, queue bound 64, "
          f"job footprint {max_fp} B):")
    print("| cap | policy | accepted | shed (mem) | pool peak | p99 lat |")
    print("|---|---|---|---|---|---|")
    for (mult, pol, r) in [(2, LEAST_LOADED, tight),
                           (2, LEAST_LOADED_BYTES, tight_bytes),
                           (5, LEAST_LOADED, roomy)]:
        print(f"| {mult}x job | {pol} | {r['accepted']} "
              f"| {r['rejected']} ({r['mem_rejected']}) "
              f"| {100*r['pool_peak']/(mult*max_fp):.0f}% "
              f"| {r['p99']*1e3:.2f} ms |")

    # the pinned §11 table (EXPERIMENTS.md) — drift fails CI
    check(max_fp == 205668352, f"largest job footprint pinned (got {max_fp})")
    # re-pinned for ISSUE-10: op-native tuned dispatch times shift the
    # arrival/completion interleaving, hence admission and pool peaks
    pinned = [
        ("tight", tight, 500, 12, 12, 411293696, 5.487784e-3),
        ("tight_bytes", tight_bytes, 501, 11, 11, 411289856, 5.569135e-3),
        ("roomy", roomy, 512, 0, 0, 653215488, 6.356940e-3),
    ]
    for (label, r, acc, rej, mem, peak, p99) in pinned:
        check(r["accepted"] == acc and r["rejected"] == rej
              and r["mem_rejected"] == mem and r["pool_peak"] == peak,
              f"§11 {label}: accepted {acc}, shed {rej} ({mem} mem), "
              f"pool peak {peak} B")
        check(abs(r["p99"] - p99) < 1e-6 * p99, f"§11 {label}: p99 pinned")

    # ---- the e2e_fleet gates ----
    speedup4 = results[2][1]["throughput"] / base
    check(speedup4 >= 3.0, f"4 devices >= 3x (got {speedup4:.2f}x)")
    for d, r in results:
        check(r["completed"] == n and r["rejected"] == 0,
              f"{d} devices: all {n} complete, none shed")
        check(r["p99"] >= r["p50"] > 0.0, f"{d} devices: sane latency quantiles")
    for (d0, r0), (d1, r1b) in zip(results, results[1:]):
        check(r1b["throughput"] >= r0["throughput"] * 0.999,
              f"throughput monotone {d0}->{d1} devices")
    check(het_ll["makespan"] <= het_rr["makespan"] * 1.001,
          f"hetero least-loaded ({het_ll['makespan']:.4f}s) <= "
          f"round-robin ({het_rr['makespan']:.4f}s)")
    check(bounded["rejected"] > 0, "bounded fleet sheds under 6x overload")
    check(bounded["accepted"] + bounded["rejected"] == n, "admission accounting")
    check(af4["completed"] == n, "affinity run completes everything")
    check(af4["spills"] == 0, "unbounded affinity never spills")
    check(af4b["spills"] > 0, "bounded affinity spills under overload")
    check(af4b["throughput"] > af4["throughput"],
          "pressure spilling beats strict pinning")

    # ---- the §11 capped-pool gates (mirror of e2e_fleet's) ----
    for (d, r) in results:
        check(r["mem_rejected"] == 0, f"{d} devices uncapped: no memory shed")
    check(tight["mem_rejected"] > 0, "2x-job caps shed on memory at 6x overload")
    check(tight["pool_peak"] <= 2 * max_fp, "tight pool peak under its cap")
    check(roomy["pool_peak"] > max_fp,
          "roomy caps co-locate >= 2 jobs on one shard")
    check(roomy["mem_rejected"] <= tight["mem_rejected"],
          "more headroom cannot shed more")
    check(roomy["accepted"] >= tight["accepted"], "more headroom cannot admit less")
    check(tight_bytes["accepted"] >= tight["accepted"],
          "bytes-aware placement admits at least as much under a tight cap")
    print(f"\nALL CHECKS PASSED (speedup at 4 devices: {speedup4:.2f}x)")


if __name__ == "__main__":
    main()
