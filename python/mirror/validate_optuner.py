#!/usr/bin/env python3
"""Replay the EXPERIMENTS.md §15 op-native tuning + filter-residency
tables without a rust toolchain, and enforce the ISSUE-10 acceptance
gate.

Checks:
  1. the §15 per-op table at n=16 (MobileNetV1 pointwise stack on the
     GTX 1080Ti): unit-tuned re-streamed floor vs op-native tuned vs
     inherited-geometry cycles, pinned bit-exact — drift fails CI;
  2. the HARD GATE: >= 1.10x geomean speedup over the residency-
     eligible suite (filter tensor >= 128 KiB and within the L2
     residency budget — the ops where cross-image filter residency has
     bytes to save and a legal place to keep them);
  3. the §15 residency-vs-re-stream table at n in {1, 4, 16, 64},
     pinned, plus the structural properties: cycles monotone in n and
     never-lose vs the re-streaming floor (tuner seeding makes the
     latter true by construction — this replays it end to end);
  4. eligibility is honest: every gated op's filter tensor fits the L2
     residency budget, and the excluded 4 MiB head (1024 -> 1024) does
     NOT fit — its row is pinned at 1.000x, not dropped silently;
  5. the satellite-2 sweep: retuning under the fused objective
     (epilogue axis included) never loses to pushing the inherited
     unfused geometry through `fused`, on every §14 model graph.

--bench-out FILE writes the replayed numbers as JSON (BENCH_10.json in
CI) so the gate numbers ride along with the build artifacts.
"""

import argparse
import json
import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))

import graph as graphmod
import ops
import tuner
from gpusim import gtx_1080ti, simulate_cycles
from plans import BYTES_F32

# ---- pinned EXPERIMENTS.md §15 values (update together with the doc) ----

# (C, W, M) -> (unit-tuned re-streamed floor, op-native tuned,
#               inherited-geometry cycles, winning plan) at n = 16
PINNED_N16 = {
    (32, 112, 64): (236371.02016528926, 235713.45983471075,
                    235713.45983471075,
                    "ours-multi[S=32 M'=64 W'x=256] xb16+fr"),
    (64, 56, 128): (120640.40198347108, 118010.16066115702,
                    118010.16066115702,
                    "ours-multi[S=32 M'=128 W'x=64] s4/cyc xb16+fr"),
    (128, 56, 128): (162724.26314049587, 157463.78049586777,
                     157463.78049586777,
                     "ours-multi[S=32 M'=128 W'x=64] s4/cyc xb16+fr"),
    (128, 28, 256): (88095.5493553719, 80175.922292011,
                     81782.97018181818,
                     "ours-multi[S=32 M'=256 W'x=32] s4/cyc xb16+fr"),
    (256, 28, 256): (139410.4677921801, 139410.4677921801,
                     139410.4677921801,
                     "ours-multi[S=64 M'=128 W'x=32] s4/cyc xb16+fr"),
    (256, 14, 512): (90179.70247933887, 78829.5356759944,
                     78829.5356759944,
                     "ours-multi[S=64 M'=128 W'x=32] s4/cyc xb16+fr"),
    (512, 14, 512): (160720.26975206615, 151647.3134537721,
                     151647.3134537721,
                     "ours-multi[S=64 M'=128 W'x=32] s4/cyc xb16+fr"),
    (512, 7, 1024): (163726.25983471074, 120114.35371900826,
                     152025.65896051418,
                     "ours-multi[S=64 M'=64 W'x=32] xb16+fr"),
    (1024, 7, 1024): (317632.9520661157, 317632.9520661157,
                      317632.9520661157,
                      "ours-multi[S=32 M'=128 W'x=32] s2/tile xb16"),
}

# the gate suite: filter tensor >= 128 KiB (residency has bytes worth
# saving) AND within the L2 residency budget (a legal place to keep
# them).  Both compute-bound members stay in — their honest 1.000x /
# 1.060x rows are part of the geomean, not cherry-picked away.
GATE_MIN_FILTER_BYTES = 128 * 1024
GATE_GEOMEAN = 1.1267
GATE_FLOOR = 1.10

# (C, W, M) -> {n: (re-streamed floor, op-native tuned)} — §15's
# residency-vs-re-stream scaling table over the gate suite
PINNED_BATCH = {
    (128, 28, 256): {
        1: (8933.15884819487, 8933.15884819487),
        4: (22285.50952588082, 22034.254912764005),
        16: (88095.5493553719, 80175.922292011),
        64: (352382.19742148754, 307466.86646831915),
    },
    (256, 28, 256): {
        1: (12915.381070417092, 12915.381070417092),
        4: (38214.39841476972, 38214.39841476972),
        16: (139410.4677921801, 139410.4677921801),
        64: (544194.7453018215, 544194.7453018215),
    },
    (256, 14, 512): {
        1: (9181.992315112851, 9181.992315112851),
        4: (23111.500987289168, 23111.500987289168),
        16: (90179.70247933887, 78829.5356759944),
        64: (360718.8099173552, 301701.674430815),
    },
    (512, 14, 512): {
        1: (13733.103426223961, 13733.103426223961),
        4: (41315.945431733606, 41315.945431733606),
        16: (160720.26975206615, 151647.3134537721),
        64: (642881.0790082641, 592972.7855419256),
    },
    (512, 7, 1024): {
        1: (14111.448932966023, 14111.448932966023),
        4: (43245.12906519742, 37208.0989825528),
        16: (163726.25983471074, 120114.35371900826),
        64: (654905.039338843, 460993.6290909091),
    },
}


def check(cond, msg):
    if not cond:
        print(f"FAIL: {msg}")
        sys.exit(1)
    print(f"ok: {msg}")


def approx(got, want, rel, msg):
    check(abs(got - want) <= rel * max(abs(want), 1e-12),
          f"{msg}: got {got:.4f}, pinned {want:.4f}")


def exact(got, want, msg):
    check(abs(got - want) <= 1e-9 * max(abs(want), 1.0),
          f"{msg}: got {got!r}, pinned {want!r}")


def filter_tensor_bytes(op):
    return op.unit().m * op.unit().c * op.unit().k * op.unit().k * BYTES_F32


def eligible(op, spec):
    fb = filter_tensor_bytes(op)
    return fb >= GATE_MIN_FILTER_BYTES and fb <= spec.l2_resident_budget()


def replay_n16(spec):
    rows = []
    print("\n| op | filter | floor (cyc) | op-native (cyc) | inherited "
          "(cyc) | speedup | plan |")
    print("|---|---|---|---|---|---|---|")
    for (c, w, m), (want_floor, want_tuned, want_inh, want_name) \
            in PINNED_N16.items():
        op = ops.ConvOp.pointwise(c, w, m)
        inherited = tuner.tuned_params(op.unit(), spec)
        floor = simulate_cycles(
            spec, tuner.build_plan(op.unit(), spec, inherited).batched(16))
        tc, params, inh = ops.tuned_op(op, ops.EP_NONE, 16, spec)
        name = ops.build_op_plan(op, ops.EP_NONE, 16, spec, params).name
        label = f"pw({c},{w},{m})"
        exact(floor, want_floor, f"§15 {label} n=16 floor")
        exact(tc, want_tuned, f"§15 {label} n=16 op-native")
        exact(inh, want_inh, f"§15 {label} n=16 inherited")
        check(name == want_name, f"§15 {label} winner: {name}")
        check(tc <= inh * (1 + 1e-9), f"§15 {label}: never loses to inherited")
        check(tc <= floor * (1 + 1e-9), f"§15 {label}: never loses to floor")
        fb = filter_tensor_bytes(op)
        rows.append({"op": label, "filter_bytes": fb, "floor": floor,
                     "tuned": tc, "inherited": inh,
                     "speedup": floor / tc, "plan": name,
                     "gated": eligible(op, spec)})
        print(f"| {label} | {fb // 1024} KiB | {floor:.0f} | {tc:.0f} "
              f"| {inh:.0f} | {floor / tc:.3f}x | {name} |")
    return rows


def gate(spec, rows):
    gated = [r for r in rows if r["gated"]]
    check(len(gated) == len(PINNED_BATCH),
          f"gate suite has {len(PINNED_BATCH)} residency-eligible ops")
    for r in rows:
        op = next((c, w, m) for (c, w, m) in PINNED_N16
                  if f"pw({c},{w},{m})" == r["op"])
        check((op in PINNED_BATCH) == r["gated"],
              f"{r['op']}: gate membership matches the pinned suite")
    # the 4 MiB head must be excluded by the budget, not by hand
    big = ops.ConvOp.pointwise(1024, 7, 1024)
    check(filter_tensor_bytes(big) > spec.l2_resident_budget(),
          "pw(1024,7,1024): 4 MiB filter tensor exceeds the L2 budget")
    gm = math.exp(sum(math.log(r["speedup"]) for r in gated) / len(gated))
    approx(gm, GATE_GEOMEAN, 0.005, "§15 gate-suite geomean")
    check(gm >= GATE_FLOOR,
          f"HARD GATE: geomean {gm:.4f}x >= {GATE_FLOOR}x on the "
          "residency-eligible MobileNetV1 pointwise suite at n=16")
    all9 = math.exp(sum(math.log(r["speedup"]) for r in rows) / len(rows))
    print(f"\ngate suite geomean {gm:.4f}x (floor {GATE_FLOOR}x); "
          f"all-9-layer geomean {all9:.4f}x")
    return gm, all9


def replay_batch_scaling(spec):
    out = {}
    print("\n| op | n | re-stream (cyc) | op-native (cyc) | saved |")
    print("|---|---|---|---|---|")
    for (c, w, m), by_n in PINNED_BATCH.items():
        op = ops.ConvOp.pointwise(c, w, m)
        label = f"pw({c},{w},{m})"
        inherited = tuner.tuned_params(op.unit(), spec)
        last = 0.0
        out[label] = {}
        for n, (want_floor, want_tuned) in sorted(by_n.items()):
            floor = simulate_cycles(
                spec, tuner.build_plan(op.unit(), spec, inherited).batched(n))
            tc = ops.tuned_op(op, ops.EP_NONE, n, spec)[0]
            exact(floor, want_floor, f"§15 {label} n={n} re-stream")
            exact(tc, want_tuned, f"§15 {label} n={n} op-native")
            check(tc <= floor * (1 + 1e-9),
                  f"§15 {label} n={n}: never loses to re-streaming")
            check(tc > last, f"§15 {label} n={n}: cycles monotone in n")
            last = tc
            out[label][n] = {"floor": floor, "tuned": tc}
            print(f"| {label} | {n} | {floor:.0f} | {tc:.0f} "
                  f"| {100 * (1 - tc / floor):.1f}% |")
    return out


def fused_retune_sweep(spec):
    # satellite 2: the tuner's Epilogue axis — retuning under the fused
    # objective never loses to the fused-inherited plan, per §14 model
    for (name, build) in graphmod.MODEL_GRAPHS:
        fused, _ = graphmod.fuse(build(), spec, graphmod.dispatch_planner)
        seen = set()
        worst = 1.0
        for node in fused.nodes:
            if node.kind != "conv":
                continue
            key = (node.conv, node.epilogue)
            if key in seen:
                continue
            seen.add(key)
            tc, _, inh = ops.tuned_op(node.conv, node.epilogue, 1, spec)
            check(tc <= inh * (1 + 1e-9),
                  f"{name}: fused-retuned beats fused-inherited on "
                  f"{node.conv.label()} +{node.epilogue}")
            worst = max(worst, tc / max(inh, 1e-12))
        print(f"ok: {name}: fused retune never loses "
              f"({len(seen)} unique fused ops)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-out", metavar="FILE",
                    help="write the replayed §15 numbers as JSON")
    args = ap.parse_args()
    spec = gtx_1080ti()

    rows = replay_n16(spec)
    gm, all9 = gate(spec, rows)
    scaling = replay_batch_scaling(spec)
    fused_retune_sweep(spec)

    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump({
                "bench": "optuner_residency",
                "device": spec.name,
                "n": 16,
                "gate_floor": GATE_FLOOR,
                "gate_geomean": gm,
                "all9_geomean": all9,
                "rows": rows,
                "batch_scaling": scaling,
            }, f, indent=2)
        print(f"\nwrote {args.bench_out}")

    print("\nALL OP-TUNER CHECKS PASSED")


if __name__ == "__main__":
    main()
