#!/usr/bin/env python3
"""Replay the EXPERIMENTS.md §14 epilogue-fusion + zero-copy-concat
tables without a rust toolchain, and prove the mirror's fusion rewrite
numerically (fused graph == unfused graph, bit for bit, through the
reference executor).

Checks:
  1. per-model fused graph shapes (node counts + fused-site counts)
     pinned to the rust fuse.rs test expectations;
  2. never-lose end to end under all three planners, and the §14
     glue-seconds reduction factors (inception3a >= 2x is the hard
     acceptance gate — the concat cell is why zero-copy exists);
  3. the zero-copy concat invariants: aliases are disjoint
     ARENA_ALIGN-aligned sub-ranges, concat glue bytes are zero, and
     the fused arena never grows;
  4. bit-identical reference outputs on fused-vs-unfused toy graphs
     (each rewrite pattern) and on alexnet + inception3a.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))

import graph as graphmod
import ops
from gpusim import EP_NONE, gtx_1080ti
from plans import BYTES_F32, ConvProblem
from reference import reference_output

# ---- pinned EXPERIMENTS.md §14 values (update together with the doc) ----

# model -> (unfused nodes, fused nodes, fused sites,
#           fused dispatched ms, glue-seconds reduction factor)
PINNED = {
    "alexnet": (11, 7, 4, 0.1297, 4.40),
    "vgg16": (32, 19, 13, 1.3031, 8.93),
    # resnet18 re-pinned for ISSUE-10: op-native geometries win on the
    # 1x1 projection layers even at n=1
    "resnet18": (44, 28, 16, 0.3317, 3.20),
    "inception3a": (16, 10, 7, 0.0563, 2.37),
    "mobilenet_v1": (56, 29, 27, 0.2076, 63.9),
}


def check(cond, msg):
    if not cond:
        print(f"FAIL: {msg}")
        sys.exit(1)
    print(f"ok: {msg}")


def approx(got, want, rel, msg):
    check(abs(got - want) <= rel * max(abs(want), 1e-12),
          f"{msg}: got {got:.4f}, pinned {want:.4f}")


def bit_equal(a, b):
    return a.shape == b.shape and a.tobytes() == b.tobytes()


def models():
    g = gtx_1080ti()
    print("| model | nodes | fused nodes | fused sites | unfused (ms) "
          "| fused (ms) | glue x |")
    print("|---|---|---|---|---|---|---|")
    for (name, build) in graphmod.MODEL_GRAPHS:
        gr = build()
        (want_n, want_fn, want_sites, want_ms, want_factor) = PINNED[name]
        check(len(gr.nodes) == want_n, f"{name}: {want_n} unfused nodes")
        # never-lose under every planner the executor accepts
        for (pname, planner) in (("paper", ops.paper_op_plan_for),
                                 ("tuned", ops.op_plan_for),
                                 ("dispatched", graphmod.dispatch_planner)):
            f, rep = graphmod.fuse(gr, g, planner)
            before = graphmod.execute(gr, g, planner)[0]
            after = graphmod.execute(f, g, planner)[0]
            check(after <= before * (1 + 1e-9),
                  f"{name}: fused never loses ({pname})")
            check(rep["glue_cycles_eliminated"] >= 0.0,
                  f"{name}: glue cycles eliminated >= 0 ({pname})")
        f, rep = graphmod.fuse(gr, g, graphmod.dispatch_planner)
        check(len(f.nodes) == want_fn, f"{name}: {want_fn} fused nodes")
        check(rep["nodes_fused"] == want_sites, f"{name}: {want_sites} fused sites")
        t0 = graphmod.execute(gr, g, graphmod.dispatch_planner)
        t1 = graphmod.execute(f, g, graphmod.dispatch_planner)
        factor = t0[2] / t1[2]
        approx(t1[0] * 1e3, want_ms, 0.01, f"§14 {name} fused dispatched graph")
        approx(factor, want_factor, 0.02, f"§14 {name} glue-seconds factor")
        # zero-copy producers stop being separate allocations, so the
        # keep-everything footprint can only shrink; the transient peak
        # may move either way (the concat allocation materializes at its
        # FIRST producer), but the greedy plan must stay fragment-free
        (p1, n1, floor1) = graphmod.plan_arena(f)
        n0 = graphmod.plan_arena(gr)[1]
        check(n1 <= n0, f"{name}: fused naive bytes {n1} <= unfused {n0}")
        check(floor1 <= p1 <= n1, f"{name}: fused arena floor <= peak <= naive")
        print(f"| {name} | {want_n} -> {want_fn} | {want_fn} | {want_sites} "
              f"| {t0[0]*1e3:.4f} | {t1[0]*1e3:.4f} | {factor:.2f}x |")
    # the §14 acceptance gate: the concat cell's glue seconds drop >= 2x
    gr = dict(graphmod.MODEL_GRAPHS)["inception3a"]()
    f, _ = graphmod.fuse(gr, g, graphmod.dispatch_planner)
    factor = (graphmod.execute(gr, g, graphmod.dispatch_planner)[2]
              / graphmod.execute(f, g, graphmod.dispatch_planner)[2])
    check(factor >= 2.0, f"§14 gate: inception3a glue seconds reduced {factor:.2f}x >= 2x")


def zero_copy():
    g = gtx_1080ti()
    gr = dict(graphmod.MODEL_GRAPHS)["inception3a"]()
    f, _ = graphmod.fuse(gr, g, graphmod.dispatch_planner)
    cats = [n for n in f.nodes if n.kind == "concat"]
    check(len(cats) == 1 and cats[0].zero_copy, "inception3a concat is zero-copy")
    cat = cats[0]
    check(graphmod.glue_bytes(f, cat) == 0.0, "zero-copy concat moves no bytes")
    aliases = graphmod.zero_copy_aliases(f)
    check(len(aliases) == len(cat.inputs), "every concat producer aliased")
    spans = []
    total = graphmod.elems(cat.shape) * BYTES_F32
    for (pid, (cid, prefix)) in sorted(aliases.items(), key=lambda kv: kv[1][1]):
        check(cid == cat.id and prefix % graphmod.ARENA_ALIGN == 0,
              f"alias {f.nodes[pid].name}: prefix {prefix} aligned")
        nbytes = graphmod.elems(f.nodes[pid].shape) * BYTES_F32
        check(prefix + nbytes <= total,
              f"alias {f.nodes[pid].name}: inside the concat allocation")
        spans.append((prefix, prefix + nbytes))
    for ((_, hi), (lo, _)) in zip(spans, spans[1:]):
        check(hi <= lo, "aliased sub-ranges are disjoint")
    # liveness: the concat materializes at its first producer's step
    lives = {l[0]: l for l in graphmod.liveness(f)}
    first = min(pid for pid in aliases)
    check(lives[cat.id][2] == first,
          "zero-copy concat live from its first producer's step")


def _toy_conv(b, name, src, p, **kw):
    return b.conv(name, src, ops.ConvOp.same(p) if p.k % 2 == 1 and p.k > 1
                  else ops.ConvOp.dense(p), **kw)


def numerics():
    g = gtx_1080ti()

    def fused_matches(build, label):
        gr = build()
        f, _ = graphmod.fuse(gr, g, ops.paper_op_plan_for)
        a, b = reference_output(gr), reference_output(f)
        check(bit_equal(a, b), f"numerics: fused == unfused bitwise ({label})")

    p = ConvProblem.multi(4, 12, 8, 3)

    def relu_tail():
        b = graphmod.Builder("t")
        x = b.input("in", (4, 12, 12))
        c = _toy_conv(b, "c", x, p)
        b.relu("r", c)
        return b

    def pool_tail():
        b = graphmod.Builder("t")
        x = b.input("in", (4, 12, 12))
        c = _toy_conv(b, "c", x, p)
        b.pool("pl", c, 2, 2)
        return b

    def through_relu():
        b = graphmod.Builder("t")
        x = b.input("in", (4, 12, 12))
        c = _toy_conv(b, "c", x, p)
        r = b.relu("r", c)
        b.pool("pl", r, 2, 2)
        return b

    def residual():
        b = graphmod.Builder("t")
        x = b.input("in", (4, 12, 12))
        c = _toy_conv(b, "c", x, ConvProblem.multi(4, 12, 4, 3))
        r = _toy_conv(b, "res", x, ConvProblem.multi(4, 12, 4, 3))
        b.add_skip("a", c, r)
        return b

    def cat():
        b = graphmod.Builder("t")
        x = b.input("in", (4, 12, 12))
        c = _toy_conv(b, "c", x, ConvProblem.multi(4, 12, 8, 3))
        d = _toy_conv(b, "d", x, ConvProblem.multi(4, 12, 8, 3))
        b.concat("cat", [c, d])
        return b

    for (build, label) in ((relu_tail, "conv+relu"), (pool_tail, "conv+pool"),
                           (through_relu, "conv+relu+pool"),
                           (residual, "add(conv, res)"), (cat, "concat")):
        fused_matches(build, label)
    fused_matches(dict(graphmod.MODEL_GRAPHS)["alexnet"], "alexnet")
    fused_matches(dict(graphmod.MODEL_GRAPHS)["inception3a"], "inception3a")


def fused_dispatch_floor():
    """decide_fused_op: cycles <= the fused naive-lowered tuned floor,
    and EP_NONE is exactly decide_op."""
    import gpusim
    g = gtx_1080ti()
    convs = []
    for (_, build) in graphmod.MODEL_GRAPHS:
        f, _ = graphmod.fuse(build(), g, graphmod.dispatch_planner)
        convs += [(n.conv, n.epilogue) for n in f.nodes
                  if n.kind == "conv" and n.epilogue != EP_NONE]
    for (op, ep) in convs:
        (_, cycles, tuned) = ops.decide_fused_op(op, ep, g)
        if cycles > tuned * (1 + 1e-9):
            print(f"FAIL: fused dispatch lost on {op.label()} +{ep}")
            sys.exit(1)
    print(f"ok: fused dispatch never loses to the fused lowered floor "
          f"({len(convs)} fused convs)")
    op = convs[0][0]
    check(ops.decide_fused_op(op, EP_NONE, g) == ops.decide_op(op, g),
          "EP_NONE dispatch is exactly the unfused ranking")


def bench_doc():
    """§14 headline numbers as the BENCH_9 artifact."""
    g = gtx_1080ti()
    out = {}
    for (name, build) in graphmod.MODEL_GRAPHS:
        gr = build()
        f, rep = graphmod.fuse(gr, g, graphmod.dispatch_planner)
        t0 = graphmod.execute(gr, g, graphmod.dispatch_planner)
        t1 = graphmod.execute(f, g, graphmod.dispatch_planner)
        out[name] = {
            "nodes": len(gr.nodes),
            "fused_nodes": len(f.nodes),
            "fused_sites": rep["nodes_fused"],
            "unfused_ms": t0[0] * 1e3,
            "fused_ms": t1[0] * 1e3,
            "glue_seconds_factor": t0[2] / t1[2],
        }
    return {"section": "EXPERIMENTS §14 fused epilogues + zero-copy concat",
            "spec": "gtx_1080ti", "models": out}


def main():
    args = sys.argv[1:]
    bench_out = None
    if "--bench-out" in args:
        bench_out = args[args.index("--bench-out") + 1]
    models()
    zero_copy()
    fused_dispatch_floor()
    numerics()
    print("\nALL FUSION CHECKS PASSED")
    if bench_out:
        import json
        Path(bench_out).write_text(json.dumps(bench_doc(), indent=1) + "\n")
        print(f"bench numbers written to {bench_out}")


if __name__ == "__main__":
    main()
