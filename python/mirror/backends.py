"""Mirror of rust/src/backend (+ rust/src/baselines): the ConvBackend
registry and the cross-backend dispatcher — cudnn proxy (implicit
GEMM), DAC'17, tan128, Winograd, FFT and the CPU host model as plans
under the shared simulator, ranked per problem with the paper-tuned
plan as the floor the dispatcher never loses to."""

import math

import tuner
from gpusim import (KernelPlan, Round, combined_efficiency,
                    segment_efficiency, simulate_cycles)
from plans import (BYTES_F32, ceil_div, stride_plan_with_choice,
                   StrideFixedChoice, working_set_bytes, wy_prime)

PAPER_TUNED = "paper-tuned"


# ---- baselines/cudnn_proxy.rs ----

TILE_SHAPES = [(128, 128, 8), (64, 128, 8), (64, 64, 8), (32, 64, 8)]


def cudnn_plan_with_tiles(p, spec, tm, tn, tk):
    assert p.valid()
    m_g = p.m
    n_g = p.oy() * p.ox()
    k_g = p.c * p.k * p.k

    m_tiles = ceil_div(m_g, tm)
    n_tiles = ceil_div(n_g, tn)
    k_steps = ceil_div(k_g, tk)
    blocks = m_tiles * n_tiles

    wave = max(min(blocks, 2 * spec.sm_count), 1)
    a_readers = min(max(wave / m_tiles, 1.0), float(n_tiles))
    b_readers = min(max(wave / n_tiles, 1.0), float(m_tiles))
    a_bytes = (tm * tk * BYTES_F32) / a_readers
    b_bytes = (tk * tn * BYTES_F32) / b_readers
    b_seg_px = min(p.ox(), tn)
    b_eff = segment_efficiency(b_seg_px * BYTES_F32)
    if p.k > 1:
        b_eff *= 0.85
    a_eff = segment_efficiency(min(tk * BYTES_F32, 128))
    eff = combined_efficiency([(a_bytes, a_eff), (b_bytes, b_eff)])

    fma_per_step = float(tm * tn * tk)
    sms_active = min(blocks, spec.sm_count)
    rounds_per_sm = ceil_div(blocks * k_steps, sms_active)
    smem = 2 * ((tm * tk + tk * tn) * BYTES_F32)

    return KernelPlan(
        name=f"cudnn-igemm[{tm}x{tn}x{tk}]",
        runs=[(Round(a_bytes + b_bytes, 128, fma_per_step, eff), rounds_per_sm)],
        sms_active=sms_active,
        threads_per_sm=1024,
        compute_efficiency=0.82,
        output_bytes=float(p.out_elems() * BYTES_F32),
        smem_bytes_per_sm=smem,
        total_fma=float(p.fma_ops()),
        launch_overhead_cycles=12_000.0,
    )


def cudnn_plan(p, spec):
    return min((cudnn_plan_with_tiles(p, spec, tm, tn, tk)
                for (tm, tn, tk) in TILE_SHAPES),
               key=lambda plan: simulate_cycles(spec, plan))


# ---- baselines/dac17.rs ----

FIXED_STRIP_ROWS = 32
DAC17_M_PRIME = 64


def dac17_plan(p, spec):
    assert p.valid()
    y_strips = ceil_div(p.wy, FIXED_STRIP_ROWS)
    x_strips = ceil_div(p.wx, FIXED_STRIP_ROWS)
    m_prime = min(DAC17_M_PRIME, p.m)
    groups = ceil_div(p.m, m_prime)
    blocks = y_strips * x_strips * groups
    sms_active = min(blocks, spec.sm_count)

    s_bytes = p.k * p.k * BYTES_F32
    segs = p.c
    filter_bytes = float(s_bytes * m_prime)
    strip_rows = min(FIXED_STRIP_ROWS, p.wy)
    strip_cols = min(FIXED_STRIP_ROWS, p.wx)
    map_bytes_per_seg = float(
        (strip_rows + p.k - 1) * (strip_cols + p.k - 1) * BYTES_F32)
    eff = combined_efficiency([
        (filter_bytes, segment_efficiency(s_bytes)),
        (map_bytes_per_seg, segment_efficiency(min(strip_cols * BYTES_F32, 128))),
    ])
    fma_per_round = float(m_prime * p.k * p.k * strip_rows * min(strip_cols, p.ox()))

    rounds_per_sm = ceil_div(blocks * segs, sms_active)
    smem = 2 * (s_bytes * m_prime
                + (strip_rows + p.k - 1) * (strip_cols + p.k - 1) * BYTES_F32)

    return KernelPlan(
        name=f"dac17[strip={FIXED_STRIP_ROWS} M'={m_prime}]",
        runs=[(Round(filter_bytes + map_bytes_per_seg, 128, fma_per_round, eff),
               rounds_per_sm)],
        sms_active=sms_active,
        threads_per_sm=1024,
        compute_efficiency=0.9,
        output_bytes=float(p.out_elems() * BYTES_F32),
        smem_bytes_per_sm=min(smem, spec.shared_mem_bytes),
        total_fma=float(p.fma_ops()),
        launch_overhead_cycles=4_000.0,
    )


# ---- baselines/tan128.rs ----

TAN_S_BYTES = 128


def tan128_plan(p, spec):
    assert p.valid() and not p.is_single_channel()
    out_px = p.oy() * p.ox()
    map_px = ceil_div(out_px, 32) * 32
    wx_prime = map_px if map_px <= 256 else 128
    half = spec.shared_mem_bytes // 2

    m_prime = min(p.m, 16)
    while m_prime > 1 and working_set_bytes(TAN_S_BYTES, wx_prime, m_prime, p.k) > half:
        m_prime //= 2

    c = StrideFixedChoice(
        TAN_S_BYTES, wx_prime, m_prime, wy_prime(TAN_S_BYTES, p.k),
        working_set_bytes(TAN_S_BYTES, wx_prime, m_prime, p.k), False)
    plan = stride_plan_with_choice(p, spec, c)
    plan.name = f"tan128[M'={m_prime}]"
    return plan


# ---- baselines/winograd.rs ----

WINO_M_PRIME = 32
WINO_C_SEG = 8


def winograd_plan(p, spec):
    assert p.valid() and p.k == 3
    tiles_y = ceil_div(p.oy(), 2)
    tiles_x = ceil_div(p.ox(), 2)
    tiles = tiles_y * tiles_x

    m_prime = min(WINO_M_PRIME, p.m)
    c_seg = min(WINO_C_SEG, p.c)
    groups = ceil_div(p.m, m_prime)
    tile_patch = 16 * 16
    patches = ceil_div(tiles, tile_patch)
    blocks = groups * patches
    sms_active = min(blocks, spec.sm_count)
    segs = ceil_div(p.c, c_seg)

    tiles_per_block = min(tiles, tile_patch)
    map_bytes = float(tiles_per_block * 5 * c_seg * BYTES_F32)
    filter_bytes = (m_prime * c_seg * 16 * BYTES_F32) / min(patches, 16)
    eff = combined_efficiency([
        (map_bytes, segment_efficiency(128)),
        (filter_bytes, segment_efficiency(64)),
    ])

    mults = float(tiles_per_block * m_prime * c_seg * 16)
    in_transform = float(tiles_per_block * c_seg * 32)
    out_transform = float(tiles_per_block * m_prime * 24) / segs
    fma_per_round = mults + in_transform + out_transform

    rounds_per_sm = ceil_div(blocks * segs, sms_active)
    smem = 2 * ((min(tiles_per_block, 64) * 16 * c_seg + m_prime * c_seg * 16) * BYTES_F32)

    return KernelPlan(
        name=f"winograd[F(2x2,3x3) M'={m_prime}]",
        runs=[(Round(map_bytes + filter_bytes, 128, fma_per_round, eff), rounds_per_sm)],
        sms_active=sms_active,
        threads_per_sm=1024,
        compute_efficiency=0.85,
        output_bytes=float(p.out_elems() * BYTES_F32),
        smem_bytes_per_sm=min(smem, spec.shared_mem_bytes // 2),
        total_fma=float(p.fma_ops()),
        launch_overhead_cycles=4_000.0,
    )


# ---- baselines/fft_conv.rs ----

def _fft2_flops(h, w):
    row = 2.5 * w * math.log2(w)
    col = 2.5 * h * math.log2(h)
    return h * row + w * col


def fft_plan(p, spec):
    assert p.valid()
    h, w = p.wy, p.wx
    spec_elems = h * (w // 2 + 1)

    fwd_maps = p.c * _fft2_flops(h, w)
    fwd_filters = (p.m * p.c) * _fft2_flops(h, w)
    pointwise = (p.m * p.c * spec_elems) * 8.0
    inverse = p.m * _fft2_flops(h, w)
    total_fma_cost = (fwd_maps + fwd_filters + pointwise + inverse) / 2.0

    bytes_in = (p.map_elems() + p.filter_elems()) * BYTES_F32
    spectra = (p.c + p.m * p.c + p.m) * spec_elems * 2 * BYTES_F32
    total_bytes = float(bytes_in + 2 * spectra)

    sms = spec.sm_count
    rounds_n = 64
    per_round_bytes = total_bytes / (sms * rounds_n)
    per_round_fma = total_fma_cost / (sms * rounds_n)

    return KernelPlan(
        name="fft-conv",
        runs=[(Round(per_round_bytes, 128, per_round_fma, 0.85), rounds_n)],
        sms_active=spec.sm_count,
        threads_per_sm=1024,
        compute_efficiency=0.8,
        output_bytes=float(p.out_elems() * BYTES_F32),
        smem_bytes_per_sm=32 * 1024,
        total_fma=float(p.fma_ops()),
        launch_overhead_cycles=12_000.0,
    )


# ---- backend/impls.rs: cpu-reference host model ----

HOST_FMA_FRACTION = 0.0625


def cpu_plan(p, spec):
    assert p.valid()
    load_bytes = float((p.map_elems() + p.filter_elems()) * BYTES_F32)
    return KernelPlan(
        name="cpu-reference[host]",
        runs=[(Round(load_bytes, 128, float(p.fma_ops())), 1)],
        sms_active=1,
        threads_per_sm=512,
        compute_efficiency=HOST_FMA_FRACTION,
        output_bytes=float(p.out_elems() * BYTES_F32),
        smem_bytes_per_sm=0,
        total_fma=float(p.fma_ops()),
        launch_overhead_cycles=0.0,
    )


# ---- backend/dispatch.rs ----

def _supports_valid(p):
    return p.valid()


def _supports_multi(p):
    return p.valid() and not p.is_single_channel()


def _supports_k3(p):
    return p.valid() and p.k == 3


def paper_plan(p, spec):
    from plans import paper_plan_for
    return paper_plan_for(p, spec)


# (name, supports, plan) — same registry order as BACKEND_NAMES, the
# paper-tuned floor handled separately in decide()
NON_TUNED_BACKENDS = [
    ("paper", _supports_valid, paper_plan),
    ("cudnn-proxy", _supports_valid, cudnn_plan),
    ("dac17", _supports_valid, dac17_plan),
    ("tan128", _supports_multi, tan128_plan),
    ("winograd", _supports_k3, winograd_plan),
    ("fft", _supports_valid, fft_plan),
    ("cpu-reference", _supports_valid, cpu_plan),
]


def backend_plan(name, p, spec):
    if name == PAPER_TUNED:
        return tuner.tuned_plan(p, spec)
    for (n, _, planfn) in NON_TUNED_BACKENDS:
        if n == name:
            return planfn(p, spec)
    raise KeyError(name)


def _decide_n(p, n, spec):
    """The one ranking routine (mirrors Dispatcher::decide_n): rank on
    batch-n schedules; batched(1) is the identity, so n=1 IS the
    single-image ranking."""
    tuned_cycles = simulate_cycles(spec, tuner.tuned_plan(p, spec).batched(n))
    best = (PAPER_TUNED, tuned_cycles)
    for (name, supports, planfn) in NON_TUNED_BACKENDS:
        if not supports(p):
            continue
        plan = planfn(p, spec)
        if not tuner.is_legal(spec, plan):
            continue
        cycles = simulate_cycles(spec, plan.batched(n))
        if cycles < best[1]:
            best = (name, cycles)
    return (best[0], best[1], tuned_cycles)


_DECIDE_CACHE = {}


def decide(p, spec):
    """(backend, cycles, tuned_cycles): fastest legal backend, with the
    paper-tuned floor it never loses to (mirrors Dispatcher::decide)."""
    key = (p, spec.name)
    if key not in _DECIDE_CACHE:
        _DECIDE_CACHE[key] = _decide_n(p, 1, spec)
    return _DECIDE_CACHE[key]


_BATCHED_CACHE = {}


def decide_batched(p, n, spec):
    """Mirrors Dispatcher::decide_batched."""
    if n == 1:
        return decide(p, spec)
    key = (p, n, spec.name)
    if key not in _BATCHED_CACHE:
        _BATCHED_CACHE[key] = _decide_n(p, n, spec)
    return _BATCHED_CACHE[key]


def dispatched_batched_seconds(p, n, spec):
    """Mirror of backend::batched_dispatch_seconds — the fleet's
    per-shard job pricing."""
    return spec.cycles_to_secs(decide_batched(p, n, spec)[1])
