"""Mirror of rust/src/analytic + rust/src/plans: the §3.1/§3.2 closed
forms and the per-SM round recipes (run-length form)."""

from dataclasses import dataclass
from typing import Optional, Tuple

from gpusim import (ExecConfig, KernelPlan, Round, mixed_round,
                    mixed_round_with_filter, simulate_cycles,
                    simulate_pipeline_runs, tagged_filter)

BYTES_F32 = 4
LAUNCH_OVERHEAD_CYCLES = 4_000.0
COMPUTE_EFFICIENCY = 0.9


@dataclass(frozen=True)
class ConvProblem:
    c: int
    wy: int
    wx: int
    m: int
    k: int

    @staticmethod
    def single(w, m, k):
        return ConvProblem(1, w, w, m, k)

    @staticmethod
    def multi(c, w, m, k):
        return ConvProblem(c, w, w, m, k)

    def is_single_channel(self):
        return self.c == 1

    def oy(self):
        return self.wy - self.k + 1

    def ox(self):
        return self.wx - self.k + 1

    def valid(self):
        return (self.c >= 1 and self.m >= 1 and self.k >= 1
                and self.k <= self.wy and self.k <= self.wx)

    def map_elems(self):
        return self.c * self.wy * self.wx

    def filter_elems(self):
        return self.m * self.c * self.k * self.k

    def out_elems(self):
        return self.m * self.oy() * self.ox()

    def fma_ops(self):
        return self.out_elems() * self.c * self.k * self.k

    def label(self):
        if self.is_single_channel():
            return f"single W={self.wy} M={self.m} K={self.k}"
        return f"multi C={self.c} W={self.wy} M={self.m} K={self.k}"


def ceil_div(a, b):
    return (a + b - 1) // b


# ---- analytic/occupancy.rs: paper launch geometry ----

def paper_threads_per_sm(spec):
    blocks = 2 * spec.sm_count
    return (blocks // spec.sm_count) * 512


# ---- analytic/single.rs ----

def d1_bytes(p, spec, pp):
    m_per_sm = ceil_div(p.m, spec.sm_count)
    return (p.k * p.k * m_per_sm + (ceil_div(p.wy, pp) + p.k - 1) * p.wx) * BYTES_F32


def th1(p, spec, pp):
    m_per_sm = ceil_div(p.m, spec.sm_count)
    return p.k * p.k * m_per_sm * ceil_div(p.wy, pp) * p.wx


def d2_bytes(p, spec, q):
    wy_per_sm = ceil_div(p.wy, spec.sm_count)
    return (p.k * p.k * ceil_div(p.m, q) + (wy_per_sm + p.k - 1) * p.wx) * BYTES_F32


def th2(p, spec, q):
    wy_per_sm = ceil_div(p.wy, spec.sm_count)
    return p.k * p.k * ceil_div(p.m, q) * wy_per_sm * p.wx


FILTER_SPLIT = "FilterSplit"
MAP_SPLIT = "MapSplit"


@dataclass(frozen=True)
class SingleChoice:
    method: str
    p: int
    q: int
    d1_bytes: int
    d2_bytes: int
    th1: int
    th2: int
    uses_prefetch: bool


def choose_single(p, spec):
    assert p.is_single_channel() and p.valid()
    n_fma = spec.n_fma()
    budget = spec.shared_mem_bytes

    m_per_sm = ceil_div(p.m, spec.sm_count)
    p_hi = min((p.k * p.k * m_per_sm * p.wy * p.wx) // n_fma, p.wy)
    wy_per_sm = ceil_div(p.wy, spec.sm_count)
    q_hi = min((p.k * p.k * p.m * wy_per_sm * p.wx) // n_fma, p.m)

    p_lo = next((pp for pp in range(1, p.wy + 1) if d1_bytes(p, spec, pp) <= budget), None)
    q_lo = next((q for q in range(1, p.m + 1) if d2_bytes(p, spec, q) <= budget), None)

    p_pick = p_lo if (p_lo is not None and p_lo <= p_hi) else None
    q_pick = q_lo if (q_lo is not None and q_lo <= q_hi) else None

    if p_pick is None and q_pick is None:
        pp, q, uses_prefetch = 1, 1, False
    elif q_pick is None:
        pp, q, uses_prefetch = p_pick, 1, True
    elif p_pick is None:
        pp, q, uses_prefetch = 1, q_pick, True
    else:
        pp, q, uses_prefetch = p_pick, q_pick, True

    d1 = d1_bytes(p, spec, pp)
    d2 = d2_bytes(p, spec, q)
    if not uses_prefetch:
        method = FILTER_SPLIT
    elif p_pick is not None and (q_pick is None or d1 <= d2):
        method = FILTER_SPLIT
    else:
        method = MAP_SPLIT

    if method == FILTER_SPLIT:
        q = 1
    else:
        pp = 1
    return SingleChoice(method, pp, q, d1_bytes(p, spec, pp), d2_bytes(p, spec, q),
                        th1(p, spec, pp), th2(p, spec, q), uses_prefetch)


def single_choice(p, spec, method, pp, q):
    d1, d2 = d1_bytes(p, spec, pp), d2_bytes(p, spec, q)
    t1, t2 = th1(p, spec, pp), th2(p, spec, q)
    d, th = (d1, t1) if method == FILTER_SPLIT else (d2, t2)
    return SingleChoice(method, pp, q, d1, d2, t1, t2,
                        th >= spec.n_fma() and d <= spec.shared_mem_bytes)


# ---- plans/single_channel.rs ----

def single_stage_bytes(p, spec, method, pp, q):
    """One pipeline-stage buffer for the single-channel schedules: the
    streamed map piece (+ halo) for FilterSplit, the streamed filter
    piece for MapSplit.  Deepening the pipeline past 2 stages costs one
    more of these per extra stage."""
    if method == FILTER_SPLIT:
        return (ceil_div(p.wy, pp) + p.k - 1) * p.wx * BYTES_F32
    return ceil_div(p.m, q) * p.k * p.k * BYTES_F32


def single_recipe(p, spec, c):
    assert p.is_single_channel()
    threads = paper_threads_per_sm(spec)
    row_seg = min(p.wx * BYTES_F32, 128)

    if c.method == FILTER_SPLIT:
        m_per_sm = ceil_div(p.m, spec.sm_count)
        sms = min(ceil_div(p.m, m_per_sm), spec.sm_count)
        filter_bytes = float(m_per_sm * p.k * p.k * BYTES_F32)
        piece_rows = ceil_div(p.wy, c.p)
        piece_bytes = (piece_rows * p.wx * BYTES_F32) / sms
        halo_bytes = ((p.k - 1) * p.wx * BYTES_F32) / sms
        fma = float(c.th1)
        filter_seg = min(m_per_sm * p.k * p.k * BYTES_F32, 128)
        first = mixed_round_with_filter(
            (filter_bytes, filter_seg),
            [(piece_bytes + halo_bytes, row_seg)], fma)
        tail = (Round(piece_bytes, row_seg, fma), c.p - 1) if c.p > 1 else None
        # the SM's ceil(M/N_sm) filters are already resident by
        # construction — pinning them across images costs their size
        return first, tail, sms, threads, c.d1_bytes, \
            single_stage_bytes(p, spec, c.method, c.p, c.q), \
            m_per_sm * p.k * p.k * BYTES_F32
    else:
        wy_per_sm = ceil_div(p.wy, spec.sm_count)
        sms = min(ceil_div(p.wy, wy_per_sm), spec.sm_count)
        strip_bytes = float((wy_per_sm + p.k - 1) * p.wx * BYTES_F32)
        m_per_round = ceil_div(p.m, c.q)
        piece_bytes = (m_per_round * p.k * p.k * BYTES_F32) / sms
        filter_seg = min(m_per_round * p.k * p.k * BYTES_F32, 128)
        fma = float(c.th2)
        first = mixed_round_with_filter(
            (piece_bytes, filter_seg),
            [(strip_bytes, row_seg)], fma)
        tail = (tagged_filter(Round(piece_bytes, filter_seg, fma),
                              piece_bytes, filter_seg),
                c.q - 1) if c.q > 1 else None
        # each SM streams ALL M filters past its strip: pinning them
        # across images costs the full filter set
        return first, tail, sms, threads, c.d2_bytes, \
            single_stage_bytes(p, spec, c.method, c.p, c.q), \
            p.m * p.k * p.k * BYTES_F32


def single_plan_with_choice(p, spec, c):
    first, tail, sms, threads, smem, stage, resident = single_recipe(p, spec, c)
    runs = [(first, 1)]
    if tail is not None:
        runs.append(tail)
    suffix = "" if c.uses_prefetch else " volume"
    return KernelPlan(
        name=f"ours-single[{c.method} P={c.p} Q={c.q}{suffix}]",
        runs=runs,
        sms_active=sms,
        threads_per_sm=threads,
        compute_efficiency=COMPUTE_EFFICIENCY,
        output_bytes=float(p.out_elems() * BYTES_F32),
        smem_bytes_per_sm=min(smem, spec.shared_mem_bytes),
        total_fma=float(p.fma_ops()),
        launch_overhead_cycles=LAUNCH_OVERHEAD_CYCLES,
        stage_bytes=stage,
        filter_resident_smem_bytes=resident,
        filter_l2_footprint_bytes=p.m * p.k * p.k * BYTES_F32,
    )


# ---- analytic/multi.rs ----

def wy_prime(s_bytes, k):
    return ceil_div(s_bytes, k * BYTES_F32)


def m_prime_min(spec, s_bytes, wx_prime):
    return ceil_div(spec.n_fma() * BYTES_F32, s_bytes * wx_prime)


def n_fma_required(spec, stages):
    """Generalized §3.2(3): with s-1 prefetches in flight each round
    need only cover 1/(s-1) of the memory latency, so the hiding
    condition relaxes to Th >= N_FMA / (s - 1)."""
    return spec.n_fma() / max(stages - 1, 1)


def stage_bytes_multi(s_bytes, wx_prime, m_prime, k):
    """One ping-pong stage of the multi-channel working set."""
    return s_bytes * m_prime + wy_prime(s_bytes, k) * wx_prime * BYTES_F32


def working_set_bytes(s_bytes, wx_prime, m_prime, k):
    return 2 * stage_bytes_multi(s_bytes, wx_prime, m_prime, k)


def staged_working_set_bytes(s_bytes, wx_prime, m_prime, k, stages):
    """Per-stage smem capacity: an s-stage pipeline holds s stage
    buffers resident."""
    return stages * stage_bytes_multi(s_bytes, wx_prime, m_prime, k)


@dataclass(frozen=True)
class StrideFixedChoice:
    s_bytes: int
    wx_prime: int
    m_prime: int
    wy_prime: int
    smem_bytes: int
    hides_latency: bool


def choose_multi(p, spec, s_bytes):
    assert p.valid() and s_bytes % 32 == 0
    out_px = p.oy() * p.ox()
    map_px = ceil_div(out_px, 32) * 32
    wx_pr = map_px if map_px <= 256 else 128

    m_prime = max(m_prime_min(spec, s_bytes, wx_pr), 1)
    if m_prime <= p.m:
        while p.m % m_prime != 0:
            m_prime += 1
    else:
        m_prime = p.m

    half = spec.shared_mem_bytes // 2
    wx_eff = wx_pr
    while working_set_bytes(s_bytes, wx_eff, m_prime, p.k) > half and m_prime > 1:
        m_prime = next((d for d in range(m_prime - 1, 0, -1) if p.m % d == 0), 1)
    while working_set_bytes(s_bytes, wx_eff, m_prime, p.k) > half and wx_eff > 32:
        wx_eff -= 32

    strips = max(ceil_div(out_px, wx_eff), 1)
    while m_prime > 1 and ceil_div(p.m, m_prime) * strips < spec.sm_count:
        nxt = next((d for d in range(m_prime - 1, 0, -1) if p.m % d == 0), 1)
        if nxt == m_prime:
            break
        m_prime = nxt

    round_fma = float(m_prime * (s_bytes // BYTES_F32) * wx_eff)
    hides = round_fma >= 0.95 * spec.n_fma()
    return StrideFixedChoice(s_bytes, wx_eff, m_prime, wy_prime(s_bytes, p.k),
                             working_set_bytes(s_bytes, wx_eff, m_prime, p.k), hides)


def multi_choice(p, spec, s_bytes, wx_pr, m_prime):
    return StrideFixedChoice(
        s_bytes, wx_pr, m_prime, wy_prime(s_bytes, p.k),
        working_set_bytes(s_bytes, wx_pr, m_prime, p.k),
        m_prime * (s_bytes // BYTES_F32) * wx_pr >= 0.95 * spec.n_fma())


# ---- plans/stride_fixed.rs ----

def stride_recipe(p, spec, c):
    assert p.valid()
    groups = ceil_div(p.m, c.m_prime)
    strips = max(ceil_div(p.oy() * p.ox(), c.wx_prime), 1)
    segs = max(ceil_div(p.c * p.k * p.k * BYTES_F32, c.s_bytes), 1)
    blocks = groups * strips
    sms_active = min(blocks, spec.sm_count)

    map_bytes = (c.wy_prime * c.wx_prime * BYTES_F32) / p.k
    filter_bytes = (c.s_bytes * c.m_prime) / min(strips, spec.sm_count)
    fma_per_round = float(c.m_prime * (c.s_bytes // BYTES_F32) * c.wx_prime)

    rnd = mixed_round_with_filter(
        (filter_bytes, c.s_bytes),
        [(map_bytes, 128)], fma_per_round)
    count = ceil_div(blocks * segs, sms_active)
    # distinct filter groups one SM walks (strips of the same group
    # revisit the same filters, so this over-counts — conservative)
    groups_per_sm = min(ceil_div(blocks, sms_active), groups)
    resident = groups_per_sm * c.m_prime * p.c * p.k * p.k * BYTES_F32
    return rnd, count, sms_active, paper_threads_per_sm(spec), resident


def stride_plan_with_choice(p, spec, c):
    rnd, count, sms, threads, resident = stride_recipe(p, spec, c)
    return KernelPlan(
        name=f"ours-multi[S={c.s_bytes} M'={c.m_prime} W'x={c.wx_prime}]",
        runs=[(rnd, count)],
        sms_active=sms,
        threads_per_sm=threads,
        compute_efficiency=COMPUTE_EFFICIENCY,
        output_bytes=float(p.out_elems() * BYTES_F32),
        smem_bytes_per_sm=c.smem_bytes,
        total_fma=float(p.fma_ops()),
        launch_overhead_cycles=LAUNCH_OVERHEAD_CYCLES,
        stage_bytes=stage_bytes_multi(c.s_bytes, c.wx_prime, c.m_prime, p.k),
        filter_resident_smem_bytes=resident,
        filter_l2_footprint_bytes=p.m * p.c * p.k * p.k * BYTES_F32,
    )


def stride_plan_with_segment_choice(p, spec, s_bytes):
    seed = choose_multi(p, spec, s_bytes)
    half = spec.shared_mem_bytes // 2
    best = None  # (cycles, choice)

    def consider(c):
        nonlocal best
        if c.smem_bytes > half:
            return
        rnd, count, sms, threads, _ = stride_recipe(p, spec, c)
        cfg = ExecConfig(sms, threads, COMPUTE_EFFICIENCY, LAUNCH_OVERHEAD_CYCLES)
        t, _ = simulate_pipeline_runs(spec, cfg, [(rnd, count)])
        if best is None or t < best[0]:
            best = (t, c)

    consider(seed)
    for d in range(1, p.m + 1):
        if p.m % d == 0:
            consider(StrideFixedChoice(
                s_bytes, seed.wx_prime, d, wy_prime(s_bytes, p.k),
                working_set_bytes(s_bytes, seed.wx_prime, d, p.k), False))
    c = best[1]
    return stride_plan_with_choice(p, spec, c), c


def stride_plan_and_choice(p, spec):
    cands = [stride_plan_with_segment_choice(p, spec, s) for s in (32, 64)]
    return min(cands, key=lambda pc: simulate_cycles(spec, pc[0]))


def paper_plan_for(p, spec):
    if p.is_single_channel():
        return single_plan_with_choice(p, spec, choose_single(p, spec))
    return stride_plan_and_choice(p, spec)[0]
