"""Mirror of rust/src/fleet/pool.rs: the per-device size-classed
exclusive memory pool.

Every transition mirrors the Rust allocator exactly — same size-class
lattice (ARENA_ALIGN = 256), exact-class LIFO reuse, carve under a hard
byte cap with largest-class-first eviction of parked slabs, exactly-once
free, and the same monotone counters — so `validate_fleet.py` can replay
the capped-fleet bench and pin its numbers without a rust toolchain.
"""

ARENA_ALIGN = 256


def size_class(nbytes):
    """Round a request up to its slab class (zero still occupies one
    minimal slab)."""
    b = max(nbytes, 1)
    return (b + ARENA_ALIGN - 1) // ARENA_ALIGN * ARENA_ALIGN


class PoolExhausted(Exception):
    def __init__(self, requested, cls, capacity, in_use_slab):
        super().__init__(
            f"pool exhausted: request {requested} B (class {cls}) vs "
            f"capacity {capacity} B with {in_use_slab} B in use")
        self.requested = requested
        self.cls = cls
        self.capacity = capacity
        self.in_use_slab = in_use_slab


class UnknownAlloc(Exception):
    def __init__(self, alloc_id):
        super().__init__(f"free of unknown allocation {alloc_id}")
        self.alloc_id = alloc_id


class DevicePool:
    def __init__(self, capacity):
        assert capacity >= ARENA_ALIGN, "pool capacity below one slab class"
        self.capacity = capacity
        self.slab_class = {}       # slab id -> class
        self.free_by_class = {}    # class -> [slab ids], LIFO within class
        self.live = {}             # alloc id -> (slab id, requested)
        self.next_slab = 1
        self.next_alloc = 1
        self.slab_total = 0        # carved bytes, free + in use (<= capacity)
        self.in_use_slab = 0
        self.in_use_requested = 0
        # PoolStats mirror
        self.allocs = 0
        self.frees = 0
        self.reuse_hits = 0
        self.carved = 0
        self.evictions = 0
        self.failed_allocs = 0
        self.peak_in_use_slab = 0
        self.peak_in_use_requested = 0

    def slab_bytes(self):
        return self.slab_total

    def in_use_slab_bytes(self):
        return self.in_use_slab

    def free_slab_bytes(self):
        return self.slab_total - self.in_use_slab

    def fragmentation_bytes(self):
        return self.in_use_slab - self.in_use_requested

    def occupancy(self):
        return self.in_use_slab / self.capacity

    def occupancy_with(self, nbytes):
        return (self.in_use_slab + size_class(nbytes)) / self.capacity

    def live_allocs(self):
        return len(self.live)

    def can_fit(self, nbytes):
        cls = size_class(nbytes)
        return bool(self.free_by_class.get(cls)) \
            or self.in_use_slab + cls <= self.capacity

    def alloc(self, nbytes):
        cls = size_class(nbytes)
        slab = self._take_free(cls)
        if slab is not None:
            self.reuse_hits += 1
        else:
            while self.slab_total + cls > self.capacity and self._evict_one():
                pass
            if self.slab_total + cls > self.capacity:
                self.failed_allocs += 1
                raise PoolExhausted(nbytes, cls, self.capacity, self.in_use_slab)
            slab = self.next_slab
            self.next_slab += 1
            self.slab_class[slab] = cls
            self.slab_total += cls
            self.carved += 1
        aid = self.next_alloc
        self.next_alloc += 1
        self.live[aid] = (slab, nbytes)
        self.in_use_slab += cls
        self.in_use_requested += nbytes
        self.allocs += 1
        self.peak_in_use_slab = max(self.peak_in_use_slab, self.in_use_slab)
        self.peak_in_use_requested = max(self.peak_in_use_requested,
                                         self.in_use_requested)
        return aid

    def free(self, aid):
        if aid not in self.live:
            raise UnknownAlloc(aid)
        slab, requested = self.live.pop(aid)
        cls = self.slab_class[slab]
        self.in_use_slab -= cls
        self.in_use_requested -= requested
        self.free_by_class.setdefault(cls, []).append(slab)
        self.frees += 1

    def evict_free(self):
        before = self.slab_total
        while self._evict_one():
            pass
        return before - self.slab_total

    def _take_free(self, cls):
        lst = self.free_by_class.get(cls)
        if not lst:
            return None
        slab = lst.pop()
        if not lst:
            del self.free_by_class[cls]
        return slab

    def _evict_one(self):
        # largest class first, most recently parked within the class
        if not self.free_by_class:
            return False
        cls = max(self.free_by_class)
        lst = self.free_by_class[cls]
        slab = lst.pop()
        if not lst:
            del self.free_by_class[cls]
        del self.slab_class[slab]
        self.slab_total -= cls
        self.evictions += 1
        return True
