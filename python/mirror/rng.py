"""Mirror of rust/src/util/rng.rs: SplitMix64 + xoshiro256** with exact
u64 wrapping semantics, so workload streams match the Rust benches
draw-for-draw."""

MASK = (1 << 64) - 1


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    def __init__(self, seed):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s = (s + 0x9E3779B97F4A7C15) & MASK
            z = s
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            self.s.append(z ^ (z >> 31))

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def range_u64(self, lo, hi):
        assert lo <= hi
        return lo + self.next_u64() % (hi - lo + 1)

    def range_usize(self, lo, hi):
        return self.range_u64(lo, hi)

    def choose(self, xs):
        return xs[self.range_usize(0, len(xs) - 1)]
