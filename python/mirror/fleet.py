"""Mirror of rust/src/fleet: virtual-time multi-GPU scheduler.  Job
pricing mirrors backend::batched_dispatch_seconds — each shard's spec
dispatches across backends for itself.  Every shard carries a
`DevicePool` (pool.py): a job's planned footprint is reserved at
placement and released at completion, the pool cap is a HARD admission
constraint for every policy, and `least-loaded-bytes` weighs predicted
completion by the pool pressure the placement would create."""

from collections import deque
from dataclasses import dataclass

import ops as opsmod
from pool import DevicePool

ROUND_ROBIN = "round-robin"
LEAST_LOADED = "least-loaded"
LEAST_LOADED_BYTES = "least-loaded-bytes"
MODEL_AFFINITY = "model-affinity"


@dataclass
class Completion:
    job: int
    device: int
    model: object
    arrival: float
    start: float
    finish: float

    def latency(self):
        return self.finish - self.arrival


class Device:
    def __init__(self, did, spec, capacity=None):
        self.id = did
        self.spec = spec
        self.queue = deque()  # (job id, finish, service, arrival, start, model, alloc)
        self.tail_finish = 0.0
        self.completed = 0
        self.busy_secs = 0.0
        # None caps at the card's DRAM — effectively unbounded for conv
        # traffic, preserving the pre-pool behavior exactly
        self.pool = DevicePool(capacity if capacity is not None else spec.dram_bytes)

    def queue_len(self):
        return len(self.queue)

    def ready_at(self, now):
        return max(self.tail_finish, now)

    def head_finish(self):
        return self.queue[0][1] if self.queue else None


class Fleet:
    def __init__(self, specs, policy, queue_bound, capacity_bytes=None):
        assert specs and queue_bound >= 1
        self.devices = [Device(i, s, capacity_bytes) for i, s in enumerate(specs)]
        self.policy = policy
        self.queue_bound = queue_bound
        self.now = 0.0
        self.rr_cursor = 0
        self.affinity = {}
        self.next_job = 1
        self.cost_cache = {}
        self.submitted = 0
        self.accepted = 0
        self.rejected = 0
        self.completed = 0
        self.batched_images = 0
        self.affinity_spills = 0
        self.mem_rejected = 0

    def advance_to(self, t):
        self.now = max(self.now, t)

    def in_flight(self):
        return sum(d.queue_len() for d in self.devices)

    def predicted_service(self, op, n, device):
        # mirror of backend::batched_op_dispatch_seconds per shard;
        # dense problems arrive as dense ops, real ops as themselves
        spec = self.devices[device].spec
        key = (op, n, spec.name)
        if key not in self.cost_cache:
            self.cost_cache[key] = opsmod.batched_op_dispatch_seconds(op, n, spec)
        return self.cost_cache[key]

    @staticmethod
    def _admissible(c):
        # queue has a slot AND the pool fits the planned footprint — the
        # pool cap is hard for every policy
        return not c[1] and c[4]

    def _least_loaded(self, cands):
        free = [c for c in cands if self._admissible(c)]
        if not free:
            return None
        return min(free, key=lambda c: (c[2] + c[3], c[0]))[0]

    def _least_loaded_bytes(self, cands):
        # minimize completion x (1 + occupancy-after-placement)
        free = [c for c in cands if self._admissible(c)]
        if not free:
            return None
        return min(free, key=lambda c: ((c[2] + c[3]) * (1.0 + c[5]), c[0]))[0]

    def submit(self, op, n, model=None):
        self.submitted += 1
        nbytes = opsmod.footprint_bytes(op, n)
        cands = []
        for i, d in enumerate(self.devices):
            cands.append((
                i,
                d.queue_len() >= self.queue_bound,  # full
                d.ready_at(self.now),
                self.predicted_service(op, n, i),
                d.pool.can_fit(nbytes),             # fits
                d.pool.occupancy_with(nbytes),      # occupancy_after
            ))

        if self.policy == ROUND_ROBIN:
            ndev = len(self.devices)
            pick = next((
                cands[(self.rr_cursor + i) % ndev][0]
                for i in range(ndev)
                if self._admissible(cands[(self.rr_cursor + i) % ndev])), None)
            if pick is not None:
                self.rr_cursor = (pick + 1) % ndev
        elif self.policy == LEAST_LOADED:
            pick = self._least_loaded(cands)
        elif self.policy == LEAST_LOADED_BYTES:
            pick = self._least_loaded_bytes(cands)
        else:  # model affinity; pin recorded on ACCEPTED placement only
            shard = self.affinity.get(model) if model is not None else None
            if shard is None:
                pick = self._least_loaded(cands)
            elif self._admissible(cands[shard]):
                pick = shard
            else:
                pick = self._least_loaded(cands)
                if pick is not None:
                    self.affinity_spills += 1

        if pick is None:
            self.rejected += 1
            if any(not c[1] for c in cands):
                # a queue slot existed somewhere — memory blocked this one
                self.mem_rejected += 1
            return None
        if self.policy == MODEL_AFFINITY and model is not None \
                and model not in self.affinity:
            self.affinity[model] = pick
        jid = self.next_job
        self.next_job += 1
        self.accepted += 1
        self.batched_images += n
        d = self.devices[pick]
        alloc = d.pool.alloc(nbytes)
        service = cands[pick][3]
        start = d.ready_at(self.now)
        finish = start + service
        d.tail_finish = finish
        d.queue.append((jid, finish, service, self.now, start, model, alloc))
        return (jid, pick, start, finish)

    def next_completion(self):
        cand = None
        for d in self.devices:
            f = d.head_finish()
            if f is not None and (cand is None or f < cand[1]):
                cand = (d.id, f)
        if cand is None:
            return None
        d = self.devices[cand[0]]
        jid, finish, service, arrival, start, model, alloc = d.queue.popleft()
        d.completed += 1
        d.busy_secs += service
        d.pool.free(alloc)
        self.now = max(self.now, finish)
        self.completed += 1
        return Completion(jid, d.id, model, arrival, start, finish)

    def complete_until(self, t):
        out = []
        while True:
            finishes = [d.head_finish() for d in self.devices
                        if d.head_finish() is not None]
            if not finishes or min(finishes) > t:
                break
            out.append(self.next_completion())
        self.advance_to(t)
        return out

    def drain(self):
        out = []
        while True:
            c = self.next_completion()
            if c is None:
                return out
            out.append(c)
