"""Mirror of rust/src/graph/reference.rs: the CPU numeric reference
executor.  Runs a graph's actual arithmetic (CHW, f32) on deterministic
pseudo-random tensors so validate_fusion.py can prove the mirror's
fusion rewrite preserves the numerics, not just the cost model.

Everything is keyed on node *names* (stable across the rewrite), and
the same relu / max-pool folds serve standalone glue nodes and fused
epilogues, so fused == unfused holds by construction wherever the
rewrite is mathematically exact.  Accumulation order inside a conv need
not match the rust executor bit-for-bit (numpy reduces pairwise); what
matters is that BOTH graphs run through these same functions."""

import numpy as np

from gpusim import EP_ADD, EP_NONE, EP_RELU, ep_pool_dims

F32 = np.float32


def relu(x):
    """Strict compare, canonical +0.0 for everything non-positive."""
    return np.where(x > 0, x, F32(0.0)).astype(F32)


def maxpool(data, shape, k, stride):
    """k x k / stride max-pool of one flattened CHW tensor."""
    c, h, w = shape
    x = np.asarray(data, dtype=F32).reshape(c, h, w)
    py, px = (h - k) // stride + 1, (w - k) // stride + 1
    out = x[:, 0:stride * py:stride, 0:stride * px:stride].copy()
    for ky in range(k):
        for kx in range(k):
            np.maximum(out, x[:, ky:ky + stride * py:stride,
                              kx:kx + stride * px:stride], out)
    return out.reshape(-1)


def seeded(name, salt, length):
    """Deterministic values in [-1, 1) from a name + salt (FNV-1a seed,
    xorshift64* stream) — same bits as reference.rs::seeded."""
    mask = (1 << 64) - 1
    h = 0xcbf29ce484222325
    for b in list(name.encode()) + [0x1F] + list(salt.encode()):
        h = ((h ^ b) * 0x00000100000001B3) & mask
    x = h | 1
    out = np.empty(length, dtype=F32)
    for i in range(length):
        x = (x ^ (x << 13)) & mask
        x ^= x >> 7
        x = (x ^ (x << 17)) & mask
        bits = ((x * 0x2545F4914F6CDD1D) & mask) >> 40
        out[i] = F32(bits / (1 << 24) * 2.0 - 1.0)
    return out


def conv(input_, in_shape, op, name):
    """Direct convolution (stride, symmetric zero padding, groups) with
    weights drawn from `name` — f32 throughout (im2col + f32 matmul)."""
    c, h, w = in_shape
    m, k = op.core.m, op.core.k
    cg = c // op.groups
    mg = m // op.groups
    oy, ox = op.oy(), op.ox()
    wts = seeded(name, "w", m * cg * k * k).reshape(m, cg * k * k)
    x = np.asarray(input_, dtype=F32).reshape(c, h, w)
    if op.pad:
        xp = np.zeros((c, h + 2 * op.pad, w + 2 * op.pad), dtype=F32)
        xp[:, op.pad:op.pad + h, op.pad:op.pad + w] = x
        x = xp
    s = op.stride
    out = np.empty((m, oy, ox), dtype=F32)
    for g in range(op.groups):
        planes = x[g * cg:(g + 1) * cg]
        cols = np.empty((cg, k, k, oy, ox), dtype=F32)
        for ky in range(k):
            for kx in range(k):
                cols[:, ky, kx] = planes[:, ky:ky + s * oy:s, kx:kx + s * ox:s]
        out[g * mg:(g + 1) * mg] = (
            wts[g * mg:(g + 1) * mg] @ cols.reshape(cg * k * k, oy * ox)
        ).reshape(mg, oy, ox)
    return out.reshape(-1)


def _eval(g, n, vals):
    ins = [(vals[i], g.nodes[i].shape) for i in n.inputs]
    if n.kind == "input":
        c, h, w = n.shape
        return seeded(n.name, "data", c * h * w)
    if n.kind == "conv":
        raw = conv(ins[0][0], ins[0][1], n.conv, n.name)
        ep = n.epilogue
        if ep == EP_NONE:
            return raw
        if ep == EP_RELU:
            return relu(raw)
        if ep == EP_ADD:
            return (raw + ins[1][0]).astype(F32)
        k, stride = ep_pool_dims(ep)
        return maxpool(raw, (n.conv.core.m, n.conv.oy(), n.conv.ox()), k, stride)
    if n.kind == "pad":
        (src, (c, sh, sw)) = ins[0]
        h, w = n.shape[1], n.shape[2]
        top, left = (h - sh) // 2, (w - sw) // 2
        out = np.zeros((c, h, w), dtype=F32)
        out[:, top:top + sh, left:left + sw] = \
            np.asarray(src, dtype=F32).reshape(c, sh, sw)
        return out.reshape(-1)
    if n.kind == "pool":
        return maxpool(ins[0][0], ins[0][1], *n.pool)
    if n.kind == "relu":
        return relu(ins[0][0])
    if n.kind == "add":
        return (ins[0][0] + ins[1][0]).astype(F32)
    if n.kind == "concat":
        return np.concatenate([np.asarray(d, dtype=F32) for (d, _) in ins])
    raise AssertionError(n.kind)


def reference_output(g):
    """Execute `g` numerically; returns the last node's flattened CHW
    tensor (np.float32)."""
    vals = []
    for n in g.nodes:
        v = _eval(g, n, vals)
        c, h, w = n.shape
        assert v.size == c * h * w, f"{n.name}: shape mismatch"
        vals.append(v)
    return vals[-1]
