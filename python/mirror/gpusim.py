"""Mirror of rust/src/gpusim: spec + memory + pipeline + sim + occupancy.

Every function mirrors its Rust namesake's arithmetic exactly (same
operation order, same integer divisions); plans are kept in run-length
form ([(round, count), ...]) which rust pins equivalent to the expanded
form (pipeline.rs::runs_form_equals_expanded_form).
"""

from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class GpuSpec:
    name: str
    mem_latency_cycles: int
    bandwidth_gb_s: float
    clock_mhz: float
    sm_count: int
    cores_per_sm: int
    fma_per_core_cycle: int
    shared_mem_bytes: int
    registers_per_sm: int
    max_threads_per_sm: int
    warp_size: int
    dram_bytes: int = 0
    l2_bytes: int = 0

    def clock_hz(self):
        return self.clock_mhz * 1e6

    def bytes_per_cycle(self):
        return self.bandwidth_gb_s * 1e9 / self.clock_hz()

    def bytes_per_cycle_int(self):
        return int(self.bytes_per_cycle())

    def fma_per_sm_cycle(self):
        return self.cores_per_sm * self.fma_per_core_cycle

    def peak_flops(self):
        return 2.0 * self.fma_per_sm_cycle() * self.sm_count * self.clock_hz()

    def n_fma(self):
        return self.mem_latency_cycles * self.fma_per_sm_cycle()

    def data_requirement_bytes(self):
        return self.bytes_per_cycle_int() * self.mem_latency_cycles

    def threads_required_total(self):
        return (self.data_requirement_bytes() + 3) // 4

    def threads_required_per_sm(self):
        per_sm = (self.threads_required_total() + self.sm_count - 1) // self.sm_count
        w = self.warp_size
        return (per_sm + w - 1) // w * w

    def data_requirement_per_sm(self):
        return self.threads_required_per_sm() * 4

    def cycles_to_secs(self, cycles):
        return cycles / self.clock_hz()

    def l2_resident_budget(self):
        """L2 capacity usable for cross-image filter residency: the
        cache minus a reserve for the streaming working set (map strips
        and writeback lines passing through)."""
        return max(self.l2_bytes - L2_STREAM_RESERVE_BYTES, 0)


# L2 lines the streaming traffic (maps in, outputs out) occupies while
# a resident filter set is held: residency only qualifies for what is
# left after this reserve.
L2_STREAM_RESERVE_BYTES = 256 * 1024


def gtx_1080ti():
    return GpuSpec("GTX 1080Ti", 258, 484.0, 1480.0, 28, 128, 2, 96 * 1024,
                   64 * 1024, 2048, 32, 11 * 1024 * 1024 * 1024,
                   2816 * 1024)


def titan_x_maxwell():
    return GpuSpec("GTX Titan X", 368, 336.5, 1000.0, 24, 128, 2, 96 * 1024,
                   64 * 1024, 2048, 32, 12 * 1024 * 1024 * 1024,
                   3 * 1024 * 1024)


# ---- memory ----

SECTOR_BYTES = 32


def useful_fraction(segment_bytes):
    assert segment_bytes > 0
    sectors = (segment_bytes + SECTOR_BYTES - 1) // SECTOR_BYTES
    return segment_bytes / (sectors * SECTOR_BYTES)


def length_factor(segment_bytes):
    if segment_bytes >= 128:
        return 1.0
    if segment_bytes >= 64:
        return 0.95
    if segment_bytes >= 32:
        return 0.90
    return 0.90 * segment_bytes / SECTOR_BYTES


def segment_efficiency(segment_bytes):
    return min(useful_fraction(segment_bytes) * length_factor(segment_bytes), 1.0)


def latency_exposure(spec, threads_per_sm, round_bytes):
    thread_fill = min(threads_per_sm / spec.threads_required_per_sm(), 1.0)
    volume_fill = min(round_bytes / spec.data_requirement_per_sm(), 1.0)
    return max(1.0 - thread_fill * volume_fill, 0.0)


# ---- pipeline ----

# Loading strategies (pipeline.rs::Loading): how one pipeline stage's
# global->shared transfer is organised across the block's warps.
CYCLIC = "cyclic"      # default round-robin; the paper's depth-2 schedule
TILEWISE = "tilewise"  # warp owns a contiguous tile: merges segments, but
                       # serializes per warp so extra stages hide nothing
ORDERED = "ordered"    # issue-ordered merge: segment gain AND stage
                       # amortization, at a per-round ordering-sync cost
LOADING_NAMES = (CYCLIC, TILEWISE, ORDERED)
LOADING_TAGS = {CYCLIC: "cyc", TILEWISE: "tile", ORDERED: "ord"}

MIN_STAGES = 2
MAX_STAGES = 4
# tilewise/ordered merge up to this many adjacent segments per issue
TILE_MERGE_SEGMENTS = 4
# per-round cost of the ordered strategy's issue-order synchronisation
ORDERED_SYNC_CYCLES = 32.0


def loading_efficiency(segment_bytes, base_eff, loading):
    """Segment-coalescing profile of a loading strategy: tilewise and
    ordered merge up to TILE_MERGE_SEGMENTS adjacent segments (capped at
    the 128-byte transaction), scaling the stream efficiency by the
    merged-over-base segment-efficiency ratio."""
    if loading == CYCLIC:
        return base_eff
    merged = max(min(TILE_MERGE_SEGMENTS * segment_bytes, 128), segment_bytes)
    gain = segment_efficiency(merged) / segment_efficiency(segment_bytes)
    return min(base_eff * gain, 1.0)


@dataclass(frozen=True)
class Round:
    load_bytes: float
    segment_bytes: int
    fma_ops: float
    eff_override: Optional[float] = None
    # share of load_bytes that is filter traffic (and its native segment)
    # — what cross-image residency can strip (pipeline.rs::Round)
    filter_bytes: float = 0.0
    filter_seg: int = 0
    # latency-hiding floor: bytes in flight even when load_bytes shrank
    # because part of the traffic is served by L2 instead of DRAM
    # (0 = load_bytes is the in-flight volume)
    inflight_bytes: float = 0.0


def mixed_round(streams, fma_ops):
    """Mirror of Round::mixed: a round fetching several constituent
    streams [(bytes, segment_bytes), ...].  Efficiency is the bus-time
    combination; the effective segment is total bytes over total segment
    issues (a bus-weighted harmonic mean) — NOT a hardcoded 128."""
    total = sum(b for b, _ in streams)
    eff = combined_efficiency(
        [(b, segment_efficiency(s)) for b, s in streams])
    issues = sum(b / s for b, s in streams if s > 0)
    seg = max(int(round(total / issues)), 1) if issues > 0 else 128
    return Round(total, seg, fma_ops, eff)


def mixed_round_with_filter(filter_stream, rest, fma_ops):
    """Mirror of Round::mixed_with_filter: a mixed round whose first
    stream is the filter traffic, remembered so residency can strip it."""
    import dataclasses
    r = mixed_round([filter_stream] + list(rest), fma_ops)
    fb, fs = filter_stream
    return dataclasses.replace(r, filter_bytes=fb, filter_seg=fs)


def tagged_filter(r, filter_bytes, filter_seg):
    """Mirror of Round::tagged_filter: mark `filter_bytes` of an
    existing round's traffic as filter loads."""
    import dataclasses
    assert filter_bytes <= r.load_bytes + 1e-9, \
        f"filter {filter_bytes} > load {r.load_bytes}"
    return dataclasses.replace(r, filter_bytes=filter_bytes,
                               filter_seg=filter_seg)


def round_without_filter_loads(r):
    """Mirror of Round::without_filter_loads: the warm-image twin of a
    round.  Filter loads still issue (they hit the resident copy, so
    the issue pattern and in-flight volume that hide latency are the
    cold round's — inflight_bytes pins that floor), but they cost no
    DRAM bus time: the round's DRAM bytes drop to the non-filter share,
    repriced by bus-time subtraction (floored at full speed)."""
    if r.filter_bytes <= 0.0:
        return r
    rem_bytes = max(r.load_bytes - r.filter_bytes, 0.0)
    if rem_bytes <= 0.0:
        return Round(0.0, r.segment_bytes, r.fma_ops, None, 0.0, 0,
                     r.load_bytes)
    eff = r.eff_override if r.eff_override is not None else \
        segment_efficiency(r.segment_bytes)
    filter_eff = segment_efficiency(max(r.filter_seg, 1))
    total_bus = r.load_bytes / max(eff, 1e-9)
    rem_bus = max(total_bus - r.filter_bytes / max(filter_eff, 1e-9),
                  rem_bytes)
    new_eff = min(rem_bytes / rem_bus, 1.0)
    return Round(rem_bytes, r.segment_bytes, r.fma_ops, new_eff, 0.0, 0,
                 r.load_bytes)


@dataclass
class ExecConfig:
    sms_active: int
    threads_per_sm: int
    compute_efficiency: float
    launch_overhead_cycles: float
    stages: int = 2
    loading: str = CYCLIC


def compute_cycles(spec, cfg, fma_ops):
    if fma_ops <= 0.0:
        return 0.0
    min_threads = 4 * spec.warp_size * (spec.cores_per_sm // spec.warp_size)
    thread_fill = min(cfg.threads_per_sm / min_threads, 1.0)
    return fma_ops / (spec.fma_per_sm_cycle() * cfg.compute_efficiency * thread_fill)


def load_cycles(spec, cfg, rnd):
    """Per-round load cost under an s-stage software pipeline: with s-1
    prefetches in flight the exposed latency is amortized by (s-1) for
    cyclic/ordered loading (tilewise serializes per warp, so depth buys
    nothing there); §3.2's hiding condition generalizes to
    Th >= N_FMA / (s-1)."""
    if rnd.load_bytes <= 0.0:
        return 0.0
    base = rnd.eff_override if rnd.eff_override is not None else segment_efficiency(
        rnd.segment_bytes)
    eff = loading_efficiency(rnd.segment_bytes, base, cfg.loading)
    per_sm_bw = spec.bytes_per_cycle() * eff / max(cfg.sms_active, 1)
    occ = min(cfg.threads_per_sm / spec.threads_required_per_sm(), 1.0)
    stream = rnd.load_bytes / (per_sm_bw * max(occ, 1e-9))
    depth = 1.0 if cfg.loading == TILEWISE else float(cfg.stages - 1)
    exposed = spec.mem_latency_cycles * latency_exposure(
        spec, cfg.threads_per_sm,
        max(rnd.load_bytes, rnd.inflight_bytes)) / depth
    sync = ORDERED_SYNC_CYCLES if cfg.loading == ORDERED else 0.0
    return exposed + stream + sync


def combined_efficiency(streams):
    total = sum(b for b, _ in streams)
    if total <= 0.0:
        return 1.0
    bus_time = sum(b / max(e, 1e-9) for b, e in streams)
    return total / bus_time


def simulate_pipeline_runs(spec, cfg, runs):
    assert runs and all(n > 0 for _, n in runs)
    loads = [load_cycles(spec, cfg, r) for r, _ in runs]
    computes = [compute_cycles(spec, cfg, r.fma_ops) for r, _ in runs]
    total = cfg.launch_overhead_cycles + spec.mem_latency_cycles + loads[0]
    stall = 0.0
    for k, (_, count) in enumerate(runs):
        if count > 1:
            total += (count - 1) * max(loads[k], computes[k])
            if loads[k] > computes[k]:
                stall += (count - 1) * (loads[k] - computes[k])
        if k + 1 < len(runs):
            total += max(loads[k + 1], computes[k])
            if loads[k + 1] > computes[k]:
                stall += loads[k + 1] - computes[k]
    total += computes[-1]
    return total, stall


# ---- sim ----

WRITEBACK_TAIL_FRACTION = 0.15

# Fused writeback epilogues (sim.rs::Epilogue), kept in tag form — the
# stable serialization the PlanCache v5 lines use: "none", "relu",
# "add", "pool{k}s{stride}".
EP_NONE = "none"
EP_RELU = "relu"
EP_ADD = "add"


def ep_pool(k, stride):
    return f"pool{k}s{stride}"


def ep_parse(tag):
    """Mirror of Epilogue::parse — None on anything unrecognised,
    otherwise the canonical tag."""
    if tag in (EP_NONE, EP_RELU, EP_ADD):
        return tag
    if tag.startswith("pool") and "s" in tag[4:]:
        k, _, stride = tag[4:].partition("s")
        try:
            k, stride = int(k), int(stride)
        except ValueError:
            return None
        if k > 0 and stride > 0:
            return ep_pool(k, stride)
    return None


def ep_pool_dims(tag):
    """(k, stride) of a pool tag, else None."""
    if not tag.startswith("pool"):
        return None
    k, _, stride = tag[4:].partition("s")
    return int(k), int(stride)


def ep_pooled_hw(tag, oy, ox):
    """Mirror of Epilogue::pooled_hw: valid-window pooled map."""
    dims = ep_pool_dims(tag)
    if dims is None:
        return oy, ox
    k, stride = dims
    assert k >= 1 and stride >= 1 and oy >= k and ox >= k, \
        f"{tag} does not fit {oy}x{ox}"
    return (oy - k) // stride + 1, (ox - k) // stride + 1


def writeback_tail_cycles(spec, output_bytes, stages):
    """Un-overlapped final store burst: the ping-pong staging is
    symmetric (outputs flush through the same s smem buffers), so the
    tail is the last stage's share — 15% of the output at the baseline
    depth 2, scaled by 2/s at deeper pipelines."""
    frac = WRITEBACK_TAIL_FRACTION * 2.0 / stages
    return frac * output_bytes / spec.bytes_per_cycle()


@dataclass
class KernelPlan:
    """Run-length plan: runs = [(Round, count), ...]."""
    name: str
    runs: List[Tuple[Round, int]]
    sms_active: int
    threads_per_sm: int
    compute_efficiency: float
    output_bytes: float
    smem_bytes_per_sm: int
    total_fma: float
    launch_overhead_cycles: float
    stages: int = 2
    loading: str = CYCLIC
    stage_bytes: int = 0
    # fused writeback epilogue (EP_NONE = the plain conv) and the bytes
    # it streams IN through the tail (the residual operand for EP_ADD)
    epilogue: str = EP_NONE
    epilogue_read_bytes: float = 0.0
    # smem cost of pinning one SM's distinct filters across batched
    # images (0 = the plan never qualifies for smem filter residency)
    filter_resident_smem_bytes: int = 0
    # total filter tensor the op touches per image — what must stay in
    # L2 for the cache-resident fallback tier (0 = never qualifies)
    filter_l2_footprint_bytes: int = 0

    def staged(self, stages, loading=CYCLIC):
        """Mirror of KernelPlan::staged: deepen the ping-pong pipeline to
        `stages` buffers under `loading`; each stage past the baseline
        two costs one more stage_bytes of shared memory."""
        assert MIN_STAGES <= stages <= MAX_STAGES, self.name
        assert loading in LOADING_NAMES, loading
        assert self.stages == 2 and self.loading == CYCLIC, self.name
        if stages == 2 and loading == CYCLIC:
            return self
        tag = f" s{stages}/{LOADING_TAGS[loading]}"
        return KernelPlan(
            name=self.name + tag,
            runs=list(self.runs),
            sms_active=self.sms_active,
            threads_per_sm=self.threads_per_sm,
            compute_efficiency=self.compute_efficiency,
            output_bytes=self.output_bytes,
            smem_bytes_per_sm=self.smem_bytes_per_sm
            + (stages - 2) * self.stage_bytes,
            total_fma=self.total_fma,
            launch_overhead_cycles=self.launch_overhead_cycles,
            stages=stages,
            loading=loading,
            stage_bytes=self.stage_bytes,
            epilogue=self.epilogue,
            epilogue_read_bytes=self.epilogue_read_bytes,
            filter_resident_smem_bytes=self.filter_resident_smem_bytes,
            filter_l2_footprint_bytes=self.filter_l2_footprint_bytes,
        )

    def batched(self, n):
        assert n >= 1
        if n == 1:
            return self
        return KernelPlan(
            name=f"{self.name} xb{n}",
            runs=list(self.runs) * n,
            sms_active=self.sms_active,
            threads_per_sm=self.threads_per_sm,
            compute_efficiency=self.compute_efficiency,
            output_bytes=self.output_bytes * n,
            smem_bytes_per_sm=self.smem_bytes_per_sm,
            total_fma=self.total_fma * n,
            launch_overhead_cycles=self.launch_overhead_cycles,
            stages=self.stages,
            loading=self.loading,
            stage_bytes=self.stage_bytes,
            epilogue=self.epilogue,
            epilogue_read_bytes=self.epilogue_read_bytes * n,
            filter_resident_smem_bytes=self.filter_resident_smem_bytes,
            filter_l2_footprint_bytes=self.filter_l2_footprint_bytes,
        )

    def resident_filter_tier(self, spec):
        """Mirror of KernelPlan::resident_filter_tier: where the filter
        working set can stay across batched images.  "smem" — one SM's
        distinct filters pinned in shared memory left after the staging
        buffers (strongest tier: no cache pressure); else "l2" — the
        op's whole filter tensor fits the L2 residency budget, so warm
        images hit cache instead of DRAM; else None."""
        if (self.filter_resident_smem_bytes > 0
                and self.smem_bytes_per_sm + self.filter_resident_smem_bytes
                <= spec.shared_mem_bytes):
            return "smem"
        if (self.filter_l2_footprint_bytes > 0
                and self.filter_l2_footprint_bytes
                <= spec.l2_resident_budget()):
            return "l2"
        return None

    def filters_can_stay_resident(self, spec):
        return self.resident_filter_tier(spec) is not None

    def batched_resident(self, n, spec):
        """Mirror of KernelPlan::batched_resident: batch n images with
        the filters resident (smem-pinned or L2) — the first image pays
        the cold rounds, the remaining n-1 run warm (filter DRAM traffic
        stripped, issue pattern and latency hiding kept).  Falls back to
        plain `batched` when no tier fits or any warm round would price
        above its cold twin."""
        assert n >= 1
        if n == 1:
            return self
        tier = self.resident_filter_tier(spec)
        if tier is None:
            return self.batched(n)
        smem_extra = self.filter_resident_smem_bytes if tier == "smem" else 0
        cfg = ExecConfig(self.sms_active, self.threads_per_sm,
                         self.compute_efficiency,
                         self.launch_overhead_cycles,
                         self.stages, self.loading)
        warm = [(round_without_filter_loads(r), c) for (r, c) in self.runs]
        wins = all(
            load_cycles(spec, cfg, w) <= load_cycles(spec, cfg, cold) + 1e-9
            for ((cold, _), (w, _)) in zip(self.runs, warm))
        if not wins:
            return self.batched(n)
        return KernelPlan(
            name=f"{self.name} xb{n}+fr",
            runs=list(self.runs) + list(warm) * (n - 1),
            sms_active=self.sms_active,
            threads_per_sm=self.threads_per_sm,
            compute_efficiency=self.compute_efficiency,
            output_bytes=self.output_bytes * n,
            smem_bytes_per_sm=self.smem_bytes_per_sm + smem_extra,
            total_fma=self.total_fma * n,
            launch_overhead_cycles=self.launch_overhead_cycles,
            stages=self.stages,
            loading=self.loading,
            stage_bytes=self.stage_bytes,
            epilogue=self.epilogue,
            epilogue_read_bytes=self.epilogue_read_bytes * n,
            filter_resident_smem_bytes=self.filter_resident_smem_bytes,
            filter_l2_footprint_bytes=self.filter_l2_footprint_bytes,
        )

    def decimated(self, keep):
        """Mirror of KernelPlan::decimated: stride handled natively by
        shrinking the output strip schedule — per-round FMAs and the
        writeback scale by `keep`, loads stay."""
        assert 0.0 < keep <= 1.0
        if keep == 1.0:
            return self
        runs = [(Round(r.load_bytes, r.segment_bytes, r.fma_ops * keep,
                       r.eff_override, r.filter_bytes, r.filter_seg), n)
                for (r, n) in self.runs]
        return KernelPlan(
            name=self.name,
            runs=runs,
            sms_active=self.sms_active,
            threads_per_sm=self.threads_per_sm,
            compute_efficiency=self.compute_efficiency,
            output_bytes=self.output_bytes * keep,
            smem_bytes_per_sm=self.smem_bytes_per_sm,
            total_fma=self.total_fma * keep,
            launch_overhead_cycles=self.launch_overhead_cycles,
            stages=self.stages,
            loading=self.loading,
            stage_bytes=self.stage_bytes,
            epilogue=self.epilogue,
            epilogue_read_bytes=self.epilogue_read_bytes * keep,
            filter_resident_smem_bytes=self.filter_resident_smem_bytes,
            filter_l2_footprint_bytes=self.filter_l2_footprint_bytes,
        )

    def grouped(self, groups, max_sms):
        """Mirror of KernelPlan::grouped: `par` groups side by side on
        idle SMs, the rest as sequential waves under one launch."""
        assert groups >= 1
        if groups == 1:
            return self
        par = min(max(max_sms // self.sms_active, 1), groups)
        waves = (groups + par - 1) // par
        return KernelPlan(
            name=f"{self.name} g{groups}",
            runs=list(self.runs) * waves,
            sms_active=self.sms_active * par,
            threads_per_sm=self.threads_per_sm,
            compute_efficiency=self.compute_efficiency,
            output_bytes=self.output_bytes * groups,
            smem_bytes_per_sm=self.smem_bytes_per_sm,
            total_fma=self.total_fma * groups,
            launch_overhead_cycles=self.launch_overhead_cycles,
            stages=self.stages,
            loading=self.loading,
            stage_bytes=self.stage_bytes,
            epilogue=self.epilogue,
            epilogue_read_bytes=self.epilogue_read_bytes * groups,
            filter_resident_smem_bytes=self.filter_resident_smem_bytes
            * waves,
            filter_l2_footprint_bytes=self.filter_l2_footprint_bytes
            * groups,
        )

    def fused(self, ep, out_hw):
        """Mirror of KernelPlan::fused: the consuming glue op absorbed
        into this plan's writeback tail.  Only valid unfused; EP_NONE is
        the identity."""
        assert self.epilogue == EP_NONE, f"{self.name}: already fused"
        if ep == EP_NONE:
            return self
        import dataclasses
        if ep == EP_RELU:
            return dataclasses.replace(self, name=f"{self.name} +relu",
                                       epilogue=ep)
        if ep == EP_ADD:
            return dataclasses.replace(self, name=f"{self.name} +add",
                                       epilogue=ep,
                                       epilogue_read_bytes=self.output_bytes)
        oy, ox = out_hw
        py, px = ep_pooled_hw(ep, oy, ox)
        frac = (py * px) / (oy * ox)
        return dataclasses.replace(self, name=f"{self.name} +{ep}",
                                   epilogue=ep,
                                   output_bytes=self.output_bytes * frac)


def plan_dram_load_bytes(plan):
    """Mirror of KernelPlan::dram_load_bytes on the run-length form."""
    return sum(r.load_bytes * n for (r, n) in plan.runs) * plan.sms_active


def plan_filter_load_bytes(plan):
    """Mirror of KernelPlan::filter_load_bytes: the filter share of the
    DRAM load traffic (what residency strips on warm images)."""
    return sum(r.filter_bytes * n for (r, n) in plan.runs) * plan.sms_active


def simulate_parts(spec, plan):
    """Mirror of simulate_detailed's cycle accounting: the pipeline
    total, its stall cycles, and the charged writeback.  The writeback
    charge is max(15% tail, DRAM bus floor excess): total time can never
    undercut moving ALL traffic (loads + stores) at peak bandwidth, so
    both roofline bandwidth fractions stay <= 1.0 (the PR-7 store-
    accounting bug this fixes)."""
    assert MIN_STAGES <= plan.stages <= MAX_STAGES, plan.name
    assert plan.loading in LOADING_NAMES, plan.name
    assert plan.smem_bytes_per_sm <= spec.shared_mem_bytes, \
        f"{plan.name}: stage smem overflow ({plan.smem_bytes_per_sm} B " \
        f"at {plan.stages} stages > {spec.shared_mem_bytes} B)"
    assert 1 <= plan.sms_active <= spec.sm_count
    cfg = ExecConfig(plan.sms_active, plan.threads_per_sm,
                     plan.compute_efficiency, plan.launch_overhead_cycles,
                     plan.stages, plan.loading)
    pipe_total, stall = simulate_pipeline_runs(spec, cfg, plan.runs)
    tail_bytes = plan.output_bytes + plan.epilogue_read_bytes
    tail = writeback_tail_cycles(spec, tail_bytes, plan.stages)
    floor = (plan_dram_load_bytes(plan) + plan.output_bytes
             + plan.epilogue_read_bytes) / spec.bytes_per_cycle()
    wb = max(tail, floor - pipe_total)
    return pipe_total, stall, tail, wb


def simulate_cycles(spec, plan):
    pipe_total, _, _, wb = simulate_parts(spec, plan)
    return pipe_total + wb


# ---- occupancy (gpusim/occupancy.rs) ----

MAX_BLOCKS_PER_SM = 32


def occupancy_blocks(spec, threads, regs_per_thread, smem_bytes):
    assert threads > 0
    by_threads = spec.max_threads_per_sm // threads
    regs_per_block = max(regs_per_thread, 1) * threads
    by_regs = spec.registers_per_sm // regs_per_block
    by_smem = (spec.shared_mem_bytes // smem_bytes) if smem_bytes else 2**32
    return min(by_threads, by_regs, by_smem, MAX_BLOCKS_PER_SM)
