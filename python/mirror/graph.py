"""Mirror of rust/src/graph: the five model graphs (op-level conv
nodes), the glue-op DRAM stream costing, the liveness + greedy best-fit
arena planner, and whole-graph execution — used to generate and gate the
EXPERIMENTS.md §7 and §10 tables without a rust toolchain."""

from dataclasses import dataclass
from typing import List, Optional, Tuple

import ops as opsmod
import suites
from gpusim import simulate_cycles
from ops import ConvOp
from plans import BYTES_F32, LAUNCH_OVERHEAD_CYCLES, ConvProblem

GLUE_BW_EFFICIENCY = 0.8
ARENA_ALIGN = 256


@dataclass
class Node:
    id: int
    name: str
    kind: str  # input | conv | pad | pool | add | concat
    shape: Tuple[int, int, int]  # (c, h, w)
    inputs: List[int]
    conv: Optional[ConvOp] = None
    pool: Optional[Tuple[int, int]] = None  # (k, stride)


class Builder:
    def __init__(self, name):
        self.name = name
        self.nodes = []

    def _add(self, name, kind, shape, inputs, **kw):
        n = Node(len(self.nodes), name, kind, shape, inputs, **kw)
        self.nodes.append(n)
        return n.id

    def input(self, name, shape):
        return self._add(name, "input", shape, [])

    def conv(self, name, src, op):
        assert op.valid(), name
        (c, h, w) = self.nodes[src].shape
        assert (c, h, w) == (op.core.c, op.core.wy, op.core.wx), \
            f"{name}: input {(c, h, w)} vs op {op.label()}"
        return self._add(name, "conv", (op.core.m, op.oy(), op.ox()), [src], conv=op)

    def conv_same(self, name, src, p):
        op = ConvOp.dense(p) if p.k == 1 else ConvOp.same(p)
        return self.conv(name, src, op)

    def pool(self, name, src, k, stride):
        (c, h, w) = self.nodes[src].shape
        return self._add(name, "pool",
                         (c, (h - k) // stride + 1, (w - k) // stride + 1), [src],
                         pool=(k, stride))

    def pad(self, name, src, h, w):
        c = self.nodes[src].shape[0]
        return self._add(name, "pad", (c, h, w), [src])

    def add_skip(self, name, a, b):
        assert self.nodes[a].shape == self.nodes[b].shape
        return self._add(name, "add", self.nodes[a].shape, [a, b])

    def concat(self, name, srcs):
        shapes = [self.nodes[s].shape for s in srcs]
        return self._add(name, "concat",
                         (sum(s[0] for s in shapes), shapes[0][1], shapes[0][2]), srcs)


def alexnet_graph():
    l = suites.alexnet()
    b = Builder("alexnet")
    x = b.input("in", (96, 27, 27))
    x = b.conv("conv2", x, l[0])
    x = b.pool("pool2", x, 3, 2)
    x = b.conv("conv3", x, l[1])
    x = b.conv("conv4", x, l[2])
    x = b.conv("conv5", x, l[3])
    b.pool("pool5", x, 3, 2)
    return b


def vgg16_graph():
    b = Builder("vgg16")
    x = b.input("in", (3, 224, 224))
    blocks = [(3, 224, 64, 2), (64, 112, 128, 2), (128, 56, 256, 3),
              (256, 28, 512, 3), (512, 14, 512, 3)]
    for bi, (c_in, w, c_out, n) in enumerate(blocks):
        for i in range(n):
            c = c_in if i == 0 else c_out
            x = b.conv_same(f"conv{bi+1}_{i+1}", x, ConvProblem.multi(c, w, c_out, 3))
        x = b.pool(f"pool{bi+1}", x, 2, 2)
    return b


def resnet18_graph():
    b = Builder("resnet18")
    x = b.input("in", (64, 56, 56))
    stages = [(64, 64, 56, 1), (64, 128, 56, 2), (128, 256, 28, 2), (256, 512, 14, 2)]
    for si, (c_in, c_out, w_in, stride) in enumerate(stages):
        s = si + 1
        w_out = (w_in - 1) // stride + 1
        for blk in (1, 2):
            transition = blk == 1 and (stride > 1 or c_in != c_out)
            if transition:
                ca = ConvOp.strided(ConvProblem.multi(c_in, w_in, c_out, 3), stride, 1)
                proj = ConvOp.strided(ConvProblem.multi(c_in, w_in, c_out, 1), stride, 0)
            else:
                ca = ConvOp.same(ConvProblem.multi(c_out, w_out, c_out, 3))
                proj = None
            cb = ConvOp.same(ConvProblem.multi(c_out, w_out, c_out, 3))
            a = b.conv(f"s{s}b{blk}c1", x, ca)
            c2 = b.conv(f"s{s}b{blk}c2", a, cb)
            skip = b.conv(f"s{s}proj", x, proj) if proj is not None else x
            x = b.add_skip(f"s{s}b{blk}add", c2, skip)
    return b


def inception3a_graph():
    br = [suites.googlenet_inception3a()[i] for i in range(6)]
    b = Builder("inception3a")
    x = b.input("in", (192, 28, 28))
    b1 = b.conv("b1.1x1", x, br[0])
    t = b.conv("b2.reduce", x, br[1])
    b2 = b.conv("b2.3x3", t, br[2])
    t = b.conv("b3.reduce", x, br[3])
    b3 = b.conv("b3.5x5", t, br[4])
    t = b.pool("b4.pool", x, 3, 1)
    t = b.pad("b4.pool.pad", t, 28, 28)
    b4 = b.conv("b4.proj", t, br[5])
    b.concat("concat", [b1, b2, b3, b4])
    return b


def mobilenet_v1_graph():
    ops = suites.mobilenet_v1()
    b = Builder("mobilenet_v1")
    x = b.input("in", (3, 224, 224))
    x = b.conv("conv1", x, ops[0])
    for i in range(1, len(ops), 2):
        blk = (i + 1) // 2
        x = b.conv(f"b{blk}.dw", x, ops[i])
        x = b.conv(f"b{blk}.pw", x, ops[i + 1])
    b.pool("avgpool", x, 7, 1)
    return b


MODEL_GRAPHS = [("alexnet", alexnet_graph), ("vgg16", vgg16_graph),
                ("resnet18", resnet18_graph), ("inception3a", inception3a_graph),
                ("mobilenet_v1", mobilenet_v1_graph)]


# ---- glue costing (mirror of graph/exec.rs) ----

def elems(shape):
    return shape[0] * shape[1] * shape[2]


def glue_bytes(g, node):
    out = elems(node.shape) * BYTES_F32
    ins = sum(elems(g.nodes[i].shape) * BYTES_F32 for i in node.inputs)
    if node.kind in ("input", "conv"):
        return 0.0
    if node.kind == "pool":
        k = node.pool[0]
        return float(elems(node.shape) * k * k * BYTES_F32 + out)
    return float(ins + out)


def glue_cycles(spec, nbytes):
    if nbytes <= 0.0:
        return 0.0
    return (LAUNCH_OVERHEAD_CYCLES + spec.mem_latency_cycles
            + nbytes / (spec.bytes_per_cycle() * GLUE_BW_EFFICIENCY))


# ---- arena planner (mirror of graph/memory.rs) ----

def _align(b):
    return (b + ARENA_ALIGN - 1) // ARENA_ALIGN * ARENA_ALIGN


def liveness(g):
    """Mirror of graph/memory.rs::liveness under the insertion-order
    schedule: [(node id, aligned bytes, def step, last use step)]."""
    order = list(range(len(g.nodes)))  # insertion order is topological
    consumers = [[] for _ in g.nodes]
    for n in g.nodes:
        for i in n.inputs:
            consumers[i].append(n.id)
    lives = []
    for nid in order:
        last = max((c for c in consumers[nid]), default=len(order) - 1)
        lives.append((nid, _align(elems(g.nodes[nid].shape) * BYTES_F32), nid, last))
    return lives


def plan_arena(g):
    order = list(range(len(g.nodes)))
    lives = liveness(g)
    naive = sum(l[1] for l in lives)
    by_size = sorted(range(len(lives)), key=lambda i: (-lives[i][1], lives[i][0]))
    placements = []  # (id, bytes, def, last, offset)
    for i in by_size:
        (nid, nbytes, d, last) = lives[i]
        busy = sorted((p[4], p[4] + p[1]) for p in placements
                      if p[2] <= last and d <= p[3])
        offset = 0
        for (lo, hi) in busy:
            if offset + nbytes <= lo:
                break
            offset = max(offset, hi)
        placements.append((nid, nbytes, d, last, offset))
    peak = max((p[4] + p[1] for p in placements), default=0)
    live_floor = 0
    for step in range(len(order)):
        live = sum(p[1] for p in placements if p[2] <= step <= p[3])
        live_floor = max(live_floor, live)
    return peak, naive, live_floor


# ---- pooled execution schedule (mirror of graph/memory.rs::plan_pooled) ----

def plan_pooled(g, pool, batch=1):
    """Walk the schedule allocating each tensor (scaled by batch) from a
    shared DevicePool at its definition step and freeing it right after
    its last use.  Returns {peak, naive, allocs, reuse, evictions}; on
    exhaustion every allocation this call made is released and the
    PoolExhausted propagates (parked-slab evictions persist)."""
    import pool as poolmod
    lives = liveness(g)
    naive = sum(l[1] * batch for l in lives)
    reuse0, evict0 = pool.reuse_hits, pool.evictions
    ids = [None] * len(lives)
    live_now = peak = 0
    for step in range(len(lives)):
        nbytes = lives[step][1] * batch
        try:
            ids[step] = pool.alloc(nbytes)
        except poolmod.PoolExhausted:
            for j, aid in enumerate(ids):
                if aid is not None:
                    pool.free(aid)
                    ids[j] = None
            raise
        live_now += nbytes
        peak = max(peak, live_now)
        for j in range(step + 1):
            if lives[j][3] == step and ids[j] is not None:
                pool.free(ids[j])
                ids[j] = None
                live_now -= lives[j][1] * batch
    assert all(aid is None for aid in ids), "every tensor freed"
    return {"peak": peak, "naive": naive, "allocs": len(lives),
            "reuse": pool.reuse_hits - reuse0,
            "evictions": pool.evictions - evict0}


# ---- execution (mirror of graph/exec.rs::execute) ----

def execute(g, spec, planner, batch=1):
    """Returns (total_s, conv_s, glue_s, per_conv_details) — planner is
    a fn(op, spec) -> KernelPlan."""
    conv_s = 0.0
    glue_s = 0.0
    details = []
    for n in g.nodes:
        if n.kind == "conv":
            plan = planner(n.conv, spec).batched(batch)
            s = spec.cycles_to_secs(simulate_cycles(spec, plan))
            conv_s += s
            details.append((n.name, n.conv, plan.name, s))
        elif n.kind != "input":
            s = spec.cycles_to_secs(glue_cycles(spec, glue_bytes(g, n) * batch))
            glue_s += s
    return conv_s + glue_s, conv_s, glue_s, details


def model_report(name, spec, planner, batch=1):
    g = dict(MODEL_GRAPHS)[name]()
    total, conv_s, glue_s, details = execute(g, spec, planner, batch)
    peak, naive, floor = plan_arena(g)
    return {
        "name": name, "nodes": len(g.nodes),
        "convs": sum(1 for n in g.nodes if n.kind == "conv"),
        "total": total, "conv": conv_s, "glue": glue_s,
        "peak": peak, "naive": naive, "floor": floor,
        "details": details,
    }


def dispatch_planner(op, spec):
    return opsmod.dispatch_op_plan(op, spec)
