"""Mirror of rust/src/graph: the five model graphs (op-level conv
nodes plus their ReLU/pool/add/concat glue), the glue-op DRAM stream
costing, the epilogue-fusion + zero-copy-concat rewrite pass, the
liveness + greedy best-fit arena planner, and whole-graph execution —
used to generate and gate the EXPERIMENTS.md §7, §10 and §14 tables
without a rust toolchain."""

from dataclasses import dataclass
from typing import List, Optional, Tuple

import ops as opsmod
import suites
from gpusim import (EP_ADD, EP_NONE, EP_RELU, ep_pool, ep_pooled_hw,
                    simulate_cycles)
from ops import ConvOp
from plans import BYTES_F32, LAUNCH_OVERHEAD_CYCLES, ConvProblem

GLUE_BW_EFFICIENCY = 0.8
ARENA_ALIGN = 256


@dataclass
class Node:
    id: int
    name: str
    kind: str  # input | conv | relu | pad | pool | add | concat
    shape: Tuple[int, int, int]  # (c, h, w)
    inputs: List[int]
    conv: Optional[ConvOp] = None
    pool: Optional[Tuple[int, int]] = None  # (k, stride)
    epilogue: str = EP_NONE  # conv nodes only (gpusim epilogue tag)
    zero_copy: bool = False  # concat nodes only


class Builder:
    def __init__(self, name):
        self.name = name
        self.nodes = []

    def _add(self, name, kind, shape, inputs, **kw):
        n = Node(len(self.nodes), name, kind, shape, inputs, **kw)
        self.nodes.append(n)
        return n.id

    def input(self, name, shape):
        return self._add(name, "input", shape, [])

    def conv(self, name, src, op, epilogue=EP_NONE):
        assert op.valid(), name
        (c, h, w) = self.nodes[src].shape
        assert (c, h, w) == (op.core.c, op.core.wy, op.core.wx), \
            f"{name}: input {(c, h, w)} vs op {op.label()}"
        py, px = ep_pooled_hw(epilogue, op.oy(), op.ox())
        return self._add(name, "conv", (op.core.m, py, px), [src],
                         conv=op, epilogue=epilogue)

    def conv_same(self, name, src, p):
        op = ConvOp.dense(p) if p.k == 1 else ConvOp.same(p)
        return self.conv(name, src, op)

    def relu(self, name, src):
        return self._add(name, "relu", self.nodes[src].shape, [src])

    def pool(self, name, src, k, stride):
        (c, h, w) = self.nodes[src].shape
        return self._add(name, "pool",
                         (c, (h - k) // stride + 1, (w - k) // stride + 1), [src],
                         pool=(k, stride))

    def pad(self, name, src, h, w):
        c = self.nodes[src].shape[0]
        return self._add(name, "pad", (c, h, w), [src])

    def add_skip(self, name, a, b):
        assert self.nodes[a].shape == self.nodes[b].shape
        return self._add(name, "add", self.nodes[a].shape, [a, b])

    def concat(self, name, srcs, zero_copy=False):
        shapes = [self.nodes[s].shape for s in srcs]
        return self._add(name, "concat",
                         (sum(s[0] for s in shapes), shapes[0][1], shapes[0][2]),
                         srcs, zero_copy=zero_copy)


def alexnet_graph():
    l = suites.alexnet()
    b = Builder("alexnet")
    x = b.input("in", (96, 27, 27))
    x = b.conv("conv2", x, l[0])
    x = b.relu("relu2", x)
    x = b.pool("pool2", x, 3, 2)
    x = b.conv("conv3", x, l[1])
    x = b.relu("relu3", x)
    x = b.conv("conv4", x, l[2])
    x = b.relu("relu4", x)
    x = b.conv("conv5", x, l[3])
    x = b.relu("relu5", x)
    b.pool("pool5", x, 3, 2)
    return b


def vgg16_graph():
    b = Builder("vgg16")
    x = b.input("in", (3, 224, 224))
    blocks = [(3, 224, 64, 2), (64, 112, 128, 2), (128, 56, 256, 3),
              (256, 28, 512, 3), (512, 14, 512, 3)]
    for bi, (c_in, w, c_out, n) in enumerate(blocks):
        for i in range(n):
            c = c_in if i == 0 else c_out
            x = b.conv_same(f"conv{bi+1}_{i+1}", x, ConvProblem.multi(c, w, c_out, 3))
            x = b.relu(f"relu{bi+1}_{i+1}", x)
        x = b.pool(f"pool{bi+1}", x, 2, 2)
    return b


def resnet18_graph():
    b = Builder("resnet18")
    x = b.input("in", (64, 56, 56))
    stages = [(64, 64, 56, 1), (64, 128, 56, 2), (128, 256, 28, 2), (256, 512, 14, 2)]
    for si, (c_in, c_out, w_in, stride) in enumerate(stages):
        s = si + 1
        w_out = (w_in - 1) // stride + 1
        for blk in (1, 2):
            transition = blk == 1 and (stride > 1 or c_in != c_out)
            if transition:
                ca = ConvOp.strided(ConvProblem.multi(c_in, w_in, c_out, 3), stride, 1)
                proj = ConvOp.strided(ConvProblem.multi(c_in, w_in, c_out, 1), stride, 0)
            else:
                ca = ConvOp.same(ConvProblem.multi(c_out, w_out, c_out, 3))
                proj = None
            cb = ConvOp.same(ConvProblem.multi(c_out, w_out, c_out, 3))
            a = b.conv(f"s{s}b{blk}c1", x, ca)
            a = b.relu(f"s{s}b{blk}relu1", a)
            c2 = b.conv(f"s{s}b{blk}c2", a, cb)
            skip = b.conv(f"s{s}proj", x, proj) if proj is not None else x
            x = b.add_skip(f"s{s}b{blk}add", c2, skip)
            x = b.relu(f"s{s}b{blk}relu2", x)
    return b


def inception3a_graph():
    br = [suites.googlenet_inception3a()[i] for i in range(6)]
    b = Builder("inception3a")
    x = b.input("in", (192, 28, 28))
    b1 = b.conv("b1.1x1", x, br[0])
    b1 = b.relu("b1.relu", b1)
    t = b.conv("b2.reduce", x, br[1])
    t = b.relu("b2.reduce.relu", t)
    b2 = b.conv("b2.3x3", t, br[2])
    b2 = b.relu("b2.relu", b2)
    t = b.conv("b3.reduce", x, br[3])
    t = b.relu("b3.reduce.relu", t)
    b3 = b.conv("b3.5x5", t, br[4])
    b3 = b.relu("b3.relu", b3)
    t = b.pool("b4.pool", x, 3, 1)
    t = b.pad("b4.pool.pad", t, 28, 28)
    b4 = b.conv("b4.proj", t, br[5])
    b4 = b.relu("b4.relu", b4)
    b.concat("concat", [b1, b2, b3, b4])
    return b


def mobilenet_v1_graph():
    ops = suites.mobilenet_v1()
    b = Builder("mobilenet_v1")
    x = b.input("in", (3, 224, 224))
    x = b.conv("conv1", x, ops[0])
    x = b.relu("conv1.relu", x)
    for i in range(1, len(ops), 2):
        blk = (i + 1) // 2
        x = b.conv(f"b{blk}.dw", x, ops[i])
        x = b.relu(f"b{blk}.dw.relu", x)
        x = b.conv(f"b{blk}.pw", x, ops[i + 1])
        x = b.relu(f"b{blk}.pw.relu", x)
    b.pool("avgpool", x, 7, 1)
    return b


MODEL_GRAPHS = [("alexnet", alexnet_graph), ("vgg16", vgg16_graph),
                ("resnet18", resnet18_graph), ("inception3a", inception3a_graph),
                ("mobilenet_v1", mobilenet_v1_graph)]


# ---- glue costing (mirror of graph/exec.rs) ----

def elems(shape):
    return shape[0] * shape[1] * shape[2]


def consumers(g):
    cons = [[] for _ in g.nodes]
    for n in g.nodes:
        for i in n.inputs:
            cons[i].append(n.id)
    return cons


def glue_bytes(g, node):
    out = elems(node.shape) * BYTES_F32
    ins = sum(elems(g.nodes[i].shape) * BYTES_F32 for i in node.inputs)
    if node.kind in ("input", "conv"):
        return 0.0
    if node.kind == "pool":
        k, stride = node.pool
        # overlap-free windows (stride >= k) touch each input pixel once
        reads = elems(g.nodes[node.inputs[0]].shape) if stride >= k \
            else elems(node.shape) * k * k
        return float(reads * BYTES_F32 + out)
    if node.kind == "concat" and node.zero_copy:
        return 0.0
    return float(ins + out)


def glue_cycles(spec, nbytes):
    if nbytes <= 0.0:
        return 0.0
    return (LAUNCH_OVERHEAD_CYCLES + spec.mem_latency_cycles
            + nbytes / (spec.bytes_per_cycle() * GLUE_BW_EFFICIENCY))


def node_glue_bytes(g, nid):
    return glue_bytes(g, g.nodes[nid])


def node_glue_cycles(g, spec, nid):
    return glue_cycles(spec, glue_bytes(g, g.nodes[nid]))


def glue_stream_cycles(spec, nbytes):
    return glue_cycles(spec, nbytes)


# ---- epilogue fusion + zero-copy concat (mirror of graph/fuse.rs) ----

def fuse(g, spec, planner):
    """Returns (fused graph, report dict).  Every rewrite is gated
    never-lose with the SAME planner + simulator the executor uses;
    planner is a fn(op, spec, ep) -> KernelPlan."""
    cons = consumers(g)

    def sole(i, c):
        return cons[i] == [c]

    def conv_of(i):
        n = g.nodes[i]
        return n.conv if n.kind == "conv" and n.epilogue == EP_NONE else None

    def conv_cycles(i, ep):
        return simulate_cycles(spec, planner(g.nodes[i].conv, spec, ep))

    claimed = [False] * len(g.nodes)
    rewrites = []  # see _rebuild for the three shapes

    # 1) residual adds first: the add pattern needs the conv's epilogue
    #    slot and eliminates the largest glue stream
    for n in g.nodes:
        if n.kind != "add":
            continue
        u, v = n.inputs
        pick = next((c for c in (u, v)
                     if conv_of(c) is not None and sole(c, n.id) and not claimed[c]),
                    None)
        if pick is None:
            continue
        residual = v if pick == u else u
        unfused = conv_cycles(pick, EP_NONE) + node_glue_cycles(g, spec, n.id)
        if conv_cycles(pick, EP_ADD) <= unfused * (1 + 1e-9):
            claimed[pick] = claimed[n.id] = True
            rewrites.append(("residual", pick, n.id, residual))

    # 2) pool tails: conv -> pool and conv -> relu -> pool
    for n in g.nodes:
        if n.kind != "pool":
            continue
        k, stride = n.pool
        ep = ep_pool(k, stride)
        r = n.inputs[0]
        if conv_of(r) is not None:
            if sole(r, n.id) and not claimed[r] and not claimed[n.id]:
                unfused = conv_cycles(r, EP_NONE) + node_glue_cycles(g, spec, n.id)
                if conv_cycles(r, ep) <= unfused * (1 + 1e-9):
                    claimed[r] = claimed[n.id] = True
                    rewrites.append(("tail", r, ep, n.id))
        elif g.nodes[r].kind == "relu" and sole(r, n.id) and not claimed[r]:
            cid = g.nodes[r].inputs[0]
            if conv_of(cid) is not None and sole(cid, r) \
                    and not claimed[cid] and not claimed[n.id]:
                # relu survives, shrunk to the pooled tensor (exact:
                # relu(maxpool(x)) == maxpool(relu(x)) elementwise)
                pooled_bytes = 2.0 * elems(n.shape) * BYTES_F32
                unfused = (conv_cycles(cid, EP_NONE)
                           + node_glue_cycles(g, spec, r)
                           + node_glue_cycles(g, spec, n.id))
                fused_c = conv_cycles(cid, ep) + glue_stream_cycles(spec, pooled_bytes)
                if fused_c <= unfused * (1 + 1e-9):
                    claimed[cid] = claimed[n.id] = True
                    rewrites.append(("through", cid, ep, r, n.id))

    # 3) plain relu tails on whatever convs are left
    for n in g.nodes:
        if n.kind != "relu" or claimed[n.id]:
            continue
        cid = n.inputs[0]
        if conv_of(cid) is None or not sole(cid, n.id) or claimed[cid]:
            continue
        unfused = conv_cycles(cid, EP_NONE) + node_glue_cycles(g, spec, n.id)
        if conv_cycles(cid, EP_RELU) <= unfused * (1 + 1e-9):
            claimed[cid] = claimed[n.id] = True
            rewrites.append(("tail", cid, EP_RELU, n.id))

    orig_bytes, orig_cycles = _total_glue(g, spec)
    f = _rebuild(g, rewrites)
    _zero_copy_concats(f)
    fused_bytes, fused_cycles = _total_glue(f, spec)
    nodes_fused = sum(1 for n in f.nodes
                      if (n.kind == "conv" and n.epilogue != EP_NONE)
                      or (n.kind == "concat" and n.zero_copy))
    return f, {"nodes_fused": nodes_fused,
               "glue_bytes_eliminated": orig_bytes - fused_bytes,
               "glue_cycles_eliminated": orig_cycles - fused_cycles}


def _rebuild(g, rewrites):
    """Walk the original nodes in id order; deleted nodes map to their
    stand-in's new id, deferred residual convs are emitted at their
    add's position (keeping the conv's name)."""
    epilogue, dead, deferred = {}, {}, {}
    for rw in rewrites:
        if rw[0] == "tail":
            _, conv, ep, d = rw
            epilogue[conv] = ep
            dead[d] = conv
        elif rw[0] == "through":
            _, conv, ep, relu, pool = rw
            epilogue[conv] = ep
            dead[pool] = relu  # pool consumers read the retained relu
        else:
            _, conv, add, residual = rw
            epilogue[conv] = EP_ADD
            deferred[add] = (conv, residual)
    deferred_convs = {conv for (conv, _) in deferred.values()}

    b = Builder(g.name)
    remap = {}

    def resolve(i):
        while i in dead:
            i = dead[i]
        return remap[i]

    for n in g.nodes:
        if n.id in dead or n.id in deferred_convs:
            continue
        if n.id in deferred:
            conv, residual = deferred[n.id]
            cn = g.nodes[conv]
            ins = [resolve(cn.inputs[0]), resolve(residual)]
            nid = _emit_conv(b, cn, ins, EP_ADD)
            remap[conv] = nid
        else:
            nid = _emit(b, n, [resolve(i) for i in n.inputs],
                        epilogue.get(n.id, n.epilogue))
        remap[n.id] = nid
    return b


def _emit_conv(b, cn, ins, ep):
    op = cn.conv
    py, px = ep_pooled_hw(ep, op.oy(), op.ox())
    return b._add(cn.name, "conv", (op.core.m, py, px), ins, conv=op, epilogue=ep)


def _emit(b, n, ins, ep):
    if n.kind == "conv":
        return _emit_conv(b, n, ins, ep)
    if n.kind == "input":
        return b.input(n.name, n.shape)
    if n.kind == "relu":
        return b._add(n.name, "relu", b.nodes[ins[0]].shape, ins)
    if n.kind == "pool":
        return b.pool(n.name, ins[0], *n.pool)
    if n.kind == "pad":
        return b.pad(n.name, ins[0], n.shape[1], n.shape[2])
    if n.kind == "add":
        return b.add_skip(n.name, ins[0], ins[1])
    if n.kind == "concat":
        return b.concat(n.name, ins, zero_copy=n.zero_copy)
    raise AssertionError(n.kind)


def _zero_copy_concats(g):
    """Flip every eligible concat in place: all inputs convs solely
    consumed by the concat, every channel-prefix offset ARENA_ALIGN."""
    cons = consumers(g)
    for n in g.nodes:
        if n.kind != "concat" or n.zero_copy:
            continue
        prefix, ok = 0, True
        for i in n.inputs:
            if g.nodes[i].kind != "conv" or cons[i] != [n.id] \
                    or prefix % ARENA_ALIGN != 0:
                ok = False
                break
            prefix += elems(g.nodes[i].shape) * BYTES_F32
        if ok:
            n.zero_copy = True


def _total_glue(g, spec):
    bytes_ = cycles = 0.0
    for n in g.nodes:
        bytes_ += node_glue_bytes(g, n.id)
        cycles += node_glue_cycles(g, spec, n.id)
    return bytes_, cycles


# ---- arena planner (mirror of graph/memory.rs) ----

def _align(b):
    return (b + ARENA_ALIGN - 1) // ARENA_ALIGN * ARENA_ALIGN


def zero_copy_aliases(g):
    """producer id -> (concat id, byte prefix) for every zero-copy
    concat input solely consumed by the concat."""
    cons = consumers(g)
    out = {}
    for n in g.nodes:
        if n.kind != "concat" or not n.zero_copy:
            continue
        prefix = 0
        for i in n.inputs:
            if cons[i] == [n.id]:
                out[i] = (n.id, prefix)
            prefix += elems(g.nodes[i].shape) * BYTES_F32
    return out


def liveness(g):
    """Mirror of graph/memory.rs::liveness under the insertion-order
    schedule: [(node id, aligned bytes, def step, last use step)].  A
    zero-copy concat's tensor is live from its earliest aliased
    producer's step."""
    order = list(range(len(g.nodes)))  # insertion order is topological
    cons = consumers(g)
    aliases = zero_copy_aliases(g)
    lives = []
    for nid in order:
        d = nid
        if g.nodes[nid].kind == "concat" and g.nodes[nid].zero_copy:
            d = min([d] + [p for p, (cid, _) in aliases.items() if cid == nid])
        last = max((c for c in cons[nid]), default=len(order) - 1)
        lives.append((nid, _align(elems(g.nodes[nid].shape) * BYTES_F32), d, last))
    return lives


def plan_arena(g):
    lives = liveness(g)
    aliases = zero_copy_aliases(g)
    owned = [l for l in lives if l[0] not in aliases]
    naive = sum(l[1] for l in owned)
    by_size = sorted(range(len(owned)), key=lambda i: (-owned[i][1], owned[i][0]))
    placements = []  # (id, bytes, def, last, offset)
    for i in by_size:
        (nid, nbytes, d, last) = owned[i]
        busy = sorted((p[4], p[4] + p[1]) for p in placements
                      if p[2] <= last and d <= p[3])
        offset = 0
        for (lo, hi) in busy:
            if offset + nbytes <= lo:
                break
            offset = max(offset, hi)
        placements.append((nid, nbytes, d, last, offset))
    peak = max((p[4] + p[1] for p in placements), default=0)
    live_floor = 0
    for step in range(len(g.nodes)):
        live = sum(p[1] for p in placements if p[2] <= step <= p[3])
        live_floor = max(live_floor, live)
    return peak, naive, live_floor


# ---- pooled execution schedule (mirror of graph/memory.rs::plan_pooled) ----

def plan_pooled(g, pool, batch=1):
    """Walk the schedule allocating each owned tensor (scaled by batch)
    from a shared DevicePool at its definition step and freeing it right
    after its last use.  A zero-copy concat materializes at its first
    producer's step; aliased producers allocate nothing.  Returns {peak,
    naive, allocs, reuse, evictions}; on exhaustion every allocation
    this call made is released and the PoolExhausted propagates
    (parked-slab evictions persist)."""
    import pool as poolmod
    lives = liveness(g)
    aliases = zero_copy_aliases(g)
    owned = [l for l in lives if l[0] not in aliases]
    naive = sum(l[1] * batch for l in owned)
    reuse0, evict0 = pool.reuse_hits, pool.evictions
    alloc_at = {}
    for j, l in enumerate(owned):
        alloc_at.setdefault(l[2], []).append(j)
    ids = [None] * len(owned)
    live_now = peak = 0
    for step in range(len(lives)):
        for j in alloc_at.get(step, []):
            nbytes = owned[j][1] * batch
            try:
                ids[j] = pool.alloc(nbytes)
            except poolmod.PoolExhausted:
                for jj, aid in enumerate(ids):
                    if aid is not None:
                        pool.free(aid)
                        ids[jj] = None
                raise
            live_now += nbytes
            peak = max(peak, live_now)
        for j, l in enumerate(owned):
            if l[3] == step and ids[j] is not None:
                pool.free(ids[j])
                ids[j] = None
                live_now -= l[1] * batch
    assert all(aid is None for aid in ids), "every tensor freed"
    return {"peak": peak, "naive": naive, "allocs": len(owned),
            "reuse": pool.reuse_hits - reuse0,
            "evictions": pool.evictions - evict0}


# ---- execution (mirror of graph/exec.rs::execute) ----

def execute(g, spec, planner, batch=1):
    """Returns (total_s, conv_s, glue_s, per_conv_details, residency) —
    planner is a fn(op, spec, ep) -> KernelPlan.  Batched serving runs
    each conv through KernelPlan.batched_resident (exec.rs::
    execute_batched); residency = (resident_conv_layers,
    resident_filter_bytes_saved)."""
    from gpusim import plan_dram_load_bytes
    conv_s = 0.0
    glue_s = 0.0
    details = []
    resident = 0
    resident_saved = 0.0
    for n in g.nodes:
        if n.kind == "conv":
            unit = planner(n.conv, spec, n.epilogue)
            plan = unit.batched_resident(batch, spec)
            if plan.name.endswith("+fr"):
                resident += 1
                resident_saved += (plan_dram_load_bytes(unit.batched(batch))
                                   - plan_dram_load_bytes(plan))
            s = spec.cycles_to_secs(simulate_cycles(spec, plan))
            conv_s += s
            details.append((n.name, n.conv, plan.name, s))
        elif n.kind != "input":
            s = spec.cycles_to_secs(glue_cycles(spec, glue_bytes(g, n) * batch))
            glue_s += s
    return conv_s + glue_s, conv_s, glue_s, details, (resident, resident_saved)


def model_report(name, spec, planner, batch=1, fused=False):
    g = dict(MODEL_GRAPHS)[name]()
    fusion = None
    if fused:
        g, fusion = fuse(g, spec, planner)
    total, conv_s, glue_s, details, residency = execute(g, spec, planner, batch)
    peak, naive, floor = plan_arena(g)
    rep = {
        "name": name, "nodes": len(g.nodes),
        "convs": sum(1 for n in g.nodes if n.kind == "conv"),
        "total": total, "conv": conv_s, "glue": glue_s,
        "peak": peak, "naive": naive, "floor": floor,
        "details": details,
        "resident_layers": residency[0], "resident_saved": residency[1],
    }
    if fusion is not None:
        rep["fusion"] = fusion
    return rep


def dispatch_planner(op, spec, ep=EP_NONE):
    return opsmod.dispatch_fused_op_plan(op, ep, spec)
