"""Validate the backend + op layer's numbers, and generate the
EXPERIMENTS.md §9/§10 tables, by replaying the Rust dispatcher's
arithmetic exactly: per-problem cross-backend ranking with the
paper-tuned plan as floor, and per-op ranking with the paper-tuned
NAIVE LOWERING (full stride-1 output, sequential groups) as floor.

Also replays the *pinned* EXPERIMENTS.md headline tables (§3/§4 means
vs the cuDNN proxy, §5 tuned-vs-paper geomeans, §7 model graphs, §10
MobileNetV1) so any drift between this mirror and the documented
numbers fails loudly.

Run: python3 python/mirror/validate_backends.py
"""

import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))

import backends
import graph
import ops
import tuner
from gpusim import (TILEWISE, gtx_1080ti, latency_exposure, simulate_cycles,
                    simulate_parts, titan_x_maxwell)
from plans import ConvProblem, paper_plan_for
from suites import (all_cnn_layers, all_cnn_ops, fig4_suite, fig5_suite,
                    mobilenet_v1, model_ops, vgg16)


def geomean(xs):
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def check(cond, msg):
    if not cond:
        print(f"FAIL: {msg}")
        sys.exit(1)
    print(f"ok: {msg}")


def approx(got, want, tol, msg):
    check(abs(got - want) <= tol, f"{msg}: got {got:.4f}, pinned {want:.4f}")


def bus_floor_bound(spec, plan):
    """True when the DRAM bus floor (not the staged store tail) sets the
    writeback charge: the row's time is pinned to moving its total
    traffic at peak bandwidth, so no schedule can beat it."""
    _, _, tail, wb = simulate_parts(spec, plan)
    return wb > tail * (1.0 + 1e-9)


def exposure_share(spec, plan):
    """Fraction of the plan's cycles that are un-hidden memory latency at
    its pipeline depth — the share deeper staging can amortize."""
    depth = 1.0 if plan.loading == TILEWISE else float(plan.stages - 1)
    exposed = sum(n * spec.mem_latency_cycles
                  * latency_exposure(spec, plan.threads_per_sm, r.load_bytes)
                  / depth
                  for (r, n) in plan.runs)
    return exposed / simulate_cycles(spec, plan)


# ---- pinned EXPERIMENTS.md values (update together with the doc) ----

PINNED = {
    # §3 / §4: paper plans vs the cuDNN proxy (means over all cases).
    # Both means dropped sharply when the DRAM bus floor entered the
    # timing (store traffic is now charged): the old 2.19x / 1.64x were
    # partly an artifact of uncharged stores on the K=1 rows.
    "fig4_vs_cudnn_mean": 1.618,
    "fig5_vs_cudnn_mean": 1.619,
    # §5: tuned vs paper-fixed geomeans (CNN suite = the 29 lowered
    # units of the op-level model suites since ISSUE-5); the tuner now
    # also sweeps the (stages, loading) axes
    "tuned_fig4": 1.019,
    "tuned_fig5": 1.179,
    "tuned_cnn": 1.182,
    "tuned_fig5_titanx": 1.258,
    # §5a: full (stages x loading) tune vs the depth-2 cyclic floor
    "staged_fig5": 1.037,
    "staged_cnn_titanx": 1.068,
    # §9: dispatch vs tuned-paper-only geomeans (Fig.4 hit 1.000: with
    # stores charged, every baseline win there was a bus-floor tie)
    "dispatch_fig4": 1.000,
    "dispatch_fig5": 1.079,
    "dispatch_cnn": 1.097,
    "dispatch_fig5_titanx": 1.086,
    # §10: op dispatch vs the naive lowered paper-tuned floor.
    # Re-pinned for ISSUE-10: op-native tuning with cross-image filter
    # residency (smem or L2 tier) lifts the batched pointwise rows.
    "op_all_models": 1.374,
    "op_mobilenet": 1.745,
    "op_mobilenet_titanx": 1.911,
    # §7 / §10 model graphs (tuned op plans, 1080Ti, milliseconds).
    # Re-pinned when the graphs gained their per-conv ReLU nodes
    # (ISSUE-9): the unfused totals now charge the relu glue streams
    # the fusion pass exists to eliminate — see §14 for the fused side.
    "graph_vgg16_tuned_ms": 2.110,
    "graph_vgg16_dispatched_ms": 1.673,
    # resnet18/mobilenet re-pinned for ISSUE-10: op-native geometries
    # win on the 1x1 projection / pointwise layers even at n=1
    "graph_resnet18_tuned_ms": 0.416,
    "graph_mobilenet_tuned_ms": 0.390,
}
# §14 (epilogue fusion + zero-copy concat) is replayed by its own
# validator: python/mirror/validate_fusion.py


def suite_speedups_tuned_vs_paper(suite, spec):
    out = []
    for p in suite:
        paper_cycles = simulate_cycles(spec, paper_plan_for(p, spec))
        tuned_cycles = simulate_cycles(spec, tuner.tuned_plan(p, spec))
        if tuned_cycles > paper_cycles * (1 + 1e-9):
            print(f"FAIL: tuner lost on {p.label()}")
            sys.exit(1)
        out.append(paper_cycles / tuned_cycles)
    return out


def suite_dispatch(suite, spec):
    rows = []
    for p in suite:
        backend, cycles, tuned_cycles = backends.decide(p, spec)
        if cycles > tuned_cycles * (1 + 1e-9):
            print(f"FAIL: dispatcher lost on {p.label()}")
            sys.exit(1)
        rows.append((p, backend, cycles, tuned_cycles))
    return rows


def dispatch_summary(name, suite, spec):
    rows = suite_dispatch(suite, spec)
    speedups = [t / c for (_, _, c, t) in rows]
    wins = {}
    for (_, b, _, _) in rows:
        if b != backends.PAPER_TUNED:
            wins[b] = wins.get(b, 0) + 1
    g = geomean(speedups)
    non_paper = sum(wins.values())
    print(f"| {name} | {non_paper}/{len(rows)} | {g:.3f}x "
          f"| {max(speedups):.2f}x | {wins} |")
    return g, rows


def op_dispatch_summary(name, suite, spec):
    speedups = []
    wins = {}
    for op in suite:
        (b, c, t) = ops.decide_op(op, spec)
        if c > t * (1 + 1e-9):
            print(f"FAIL: op dispatcher lost on {op.label()}")
            sys.exit(1)
        speedups.append(t / c)
        if b != backends.PAPER_TUNED:
            wins[b] = wins.get(b, 0) + 1
    g = geomean(speedups)
    print(f"| {name} | {sum(wins.values())}/{len(suite)} | {g:.3f}x "
          f"| {max(speedups):.2f}x | {wins} |")
    return g


def main():
    g = gtx_1080ti()
    tx = titan_x_maxwell()

    # ---- §3 / §4 replay: paper plans vs the cuDNN proxy ----
    # Since the store-accounting fix, a row where BOTH plans sit on the
    # DRAM bus floor is a physics tie: neither schedule can beat moving
    # the total traffic at peak bandwidth, and ours may carry slightly
    # more filter re-stream traffic.  Those documented rows may tie
    # within 1%; everywhere else ours must strictly win.
    for (name, suite, pin) in [("fig4", fig4_suite(), "fig4_vs_cudnn_mean"),
                               ("fig5", fig5_suite(), "fig5_vs_cudnn_mean")]:
        speedups = []
        losses = []
        floor_ties = 0
        for p in suite:
            ours_plan = paper_plan_for(p, g)
            base_plan = backends.cudnn_plan(p, g)
            s = simulate_cycles(g, base_plan) / simulate_cycles(g, ours_plan)
            speedups.append(s)
            if s <= 1.0:
                if (s > 0.99 and bus_floor_bound(g, ours_plan)
                        and bus_floor_bound(g, base_plan)):
                    floor_ties += 1
                else:
                    losses.append(p.label())
        check(not losses,
              f"{name}: ours wins or floor-ties every case "
              f"({floor_ties} floor ties; losses: {losses})")
        approx(sum(speedups) / len(speedups), PINNED[pin], 0.02,
               f"{name} mean vs cudnn proxy")

    # ---- §5 replay: tuned vs paper geomeans ----
    approx(geomean(suite_speedups_tuned_vs_paper(fig4_suite(), g)),
           PINNED["tuned_fig4"], 0.005, "§5 Fig.4 tuned geomean")
    approx(geomean(suite_speedups_tuned_vs_paper(fig5_suite(), g)),
           PINNED["tuned_fig5"], 0.005, "§5 Fig.5 tuned geomean")
    approx(geomean(suite_speedups_tuned_vs_paper(all_cnn_layers(), g)),
           PINNED["tuned_cnn"], 0.005, "§5 CNN-unit tuned geomean")
    approx(geomean(suite_speedups_tuned_vs_paper(fig5_suite(), tx)),
           PINNED["tuned_fig5_titanx"], 0.005, "§5 Fig.5 Titan X tuned geomean")

    # ---- §5a: the multi-stage pipeline axis (tentpole gate) ----
    # Never-lose: the full (geometry x stages x loading) tune includes
    # the depth-2 cyclic subspace, so it can never lose to that floor.
    staged_vs_d2 = {}
    for (spec, sname) in ((g, "1080ti"), (tx, "titanx")):
        for (sn, suite) in (("fig4", fig4_suite()), ("fig5", fig5_suite()),
                            ("cnn", all_cnn_layers())):
            ratios = []
            for p in suite:
                d2 = simulate_cycles(spec, tuner.depth2_tuned_plan(p, spec))
                full = simulate_cycles(spec, tuner.tuned_plan(p, spec))
                if full > d2 * (1 + 1e-9):
                    print(f"FAIL: multi-stage lost to depth-2 on "
                          f"{p.label()} ({spec.name})")
                    sys.exit(1)
                ratios.append(d2 / full)
            staged_vs_d2[(sname, sn)] = geomean(ratios)
    print("ok: full (stages x loading) tune never loses to the depth-2 "
          "floor (both specs, all suites)")
    approx(staged_vs_d2[("1080ti", "fig5")], PINNED["staged_fig5"],
           0.005, "§5a Fig.5 staged-vs-depth2 geomean")
    approx(staged_vs_d2[("titanx", "cnn")], PINNED["staged_cnn_titanx"],
           0.005, "§5a CNN Titan X staged-vs-depth2 geomean")

    # The acceptance gate: on the latency-exposed Fig.4 rows (depth-2
    # exposure share above 3% and not pinned to the DRAM bus floor),
    # deeper pipelines must buy a >= 1.05x geomean.
    exposed = []
    for p in fig4_suite():
        d2p = tuner.depth2_tuned_plan(p, g)
        if exposure_share(g, d2p) > 0.03 and not bus_floor_bound(g, d2p):
            exposed.append(simulate_cycles(g, d2p)
                           / simulate_cycles(g, tuner.tuned_plan(p, g)))
    check(len(exposed) >= 3,
          f"enough latency-exposed Fig.4 rows to gate on ({len(exposed)})")
    gate = geomean(exposed)
    check(gate >= 1.05,
          f"multi-stage gate: >=1.05x geomean on the {len(exposed)} "
          f"latency-exposed Fig.4 rows (got {gate:.4f}x)")
    picks = {}
    for p in list(fig4_suite()) + list(fig5_suite()):
        plan = tuner.tuned_plan(p, g)
        key = f"{plan.stages}/{plan.loading}"
        picks[key] = picks.get(key, 0) + 1
    check(any(k.split("/")[0] != "2" for k in picks),
          f"tuner picks deeper pipelines somewhere: {picks}")

    # ---- §9: the dispatcher ----
    print("\n| suite | non-paper wins | geomean | max | winners |")
    print("|---|---|---|---|---|")
    g4, _ = dispatch_summary("Fig. 4 (18 single-channel)", fig4_suite(), g)
    g5, rows5 = dispatch_summary("Fig. 5 (21 multi-channel)", fig5_suite(), g)
    gc, rowsc = dispatch_summary("CNN units (29)", all_cnn_layers(), g)
    gt, _ = dispatch_summary("Fig. 5 on Titan X", fig5_suite(), tx)

    approx(g4, PINNED["dispatch_fig4"], 0.005, "§9 Fig.4 dispatch geomean")
    approx(g5, PINNED["dispatch_fig5"], 0.005, "§9 Fig.5 dispatch geomean")
    approx(gc, PINNED["dispatch_cnn"], 0.005, "§9 CNN dispatch geomean")
    approx(gt, PINNED["dispatch_fig5_titanx"], 0.005, "§9 Titan X dispatch geomean")

    check(max(g4, g5, gc, gt) > 1.001, "a baseline legitimately wins somewhere")

    # the regime checks the Rust tests pin
    b, _, _ = backends.decide(ConvProblem.multi(256, 56, 256, 3), g)
    check(b == "winograd", f"winograd wins the big K=3 layer (got {b})")
    b, _, _ = backends.decide(ConvProblem.multi(256, 14, 256, 1), g)
    check(b == backends.PAPER_TUNED,
          f"paper kernel keeps its small-map K=1 home turf (got {b})")
    for (p, b, _, _) in rows5 + rowsc:
        if b == "cpu-reference":
            print(f"FAIL: cpu-reference dispatched on {p.label()}")
            sys.exit(1)
    print("ok: cpu-reference never dispatched")
    # per-layer algorithm choice at the op level: VGG-16's 'same' body
    # (C >= 64) goes fully Winograd — its padded units are all big K=3 —
    # while the C=3 stem layer is bus-floor-bound since the store-
    # accounting fix, so winograd's FLOP savings buy nothing there and
    # the paper kernel keeps it
    vgg_body = {ops.decide_op(o, g)[0] for o in vgg16() if o.core.c >= 64}
    check(vgg_body == {"winograd"},
          f"VGG-16 'same' body (C>=64) dispatches to winograd: {sorted(vgg_body)}")
    stem = [o for o in vgg16() if o.core.c < 64]
    check(stem and all(ops.decide_op(o, g)[0] == backends.PAPER_TUNED
                       for o in stem),
          "VGG-16 C=3 stem stays on the paper kernel (bus-floor-bound)")
    mb_backends = {ops.decide_op(o, g)[0] for o in mobilenet_v1()}
    check(len(mb_backends) > 1 and backends.PAPER_TUNED in mb_backends,
          f"MobileNetV1 mixes backends per layer: {sorted(mb_backends)}")

    # ---- §10: the op layer (stride / pad / groups) ----
    print("\n| op suite | non-paper wins | geomean vs lowered floor | max | winners |")
    print("|---|---|---|---|---|")
    go = op_dispatch_summary("All model ops (48)", all_cnn_ops(), g)
    gm = op_dispatch_summary("MobileNetV1 (27 ops)", mobilenet_v1(), g)
    gmt = op_dispatch_summary("MobileNetV1 on Titan X", mobilenet_v1(), tx)
    approx(go, PINNED["op_all_models"], 0.005, "§10 all-model-ops geomean")
    approx(gm, PINNED["op_mobilenet"], 0.005, "§10 MobileNetV1 geomean")
    approx(gmt, PINNED["op_mobilenet_titanx"], 0.005, "§10 MobileNetV1 Titan X geomean")
    # on both specs, EVERY model op respects the lowered floor
    for spec in (g, tx):
        for op in all_cnn_ops():
            (_, c, t) = ops.decide_op(op, spec)
            if c > t * (1 + 1e-9):
                print(f"FAIL: {op.label()} lost on {spec.name}")
                sys.exit(1)
    print("ok: op dispatch never loses to the lowered floor (both specs, all 48 ops)")
    # the native strided schedule genuinely beats the naive lowering
    s2 = ops.ConvOp.strided(ConvProblem.multi(64, 56, 128, 3), 2, 1)
    nat = simulate_cycles(g, ops.op_plan_for(s2, g))
    low = simulate_cycles(g, ops.lowered_plan(tuner.tuned_plan, s2, g))
    check(nat < low * 0.95, f"native stride-2 wins ({nat:.0f} vs lowered {low:.0f})")
    dw = ops.ConvOp.depthwise(512, 14, 3, 1)
    natd = simulate_cycles(g, ops.op_plan_for(dw, g))
    lowd = simulate_cycles(g, ops.lowered_plan(tuner.tuned_plan, dw, g))
    check(natd < 0.5 * lowd, f"grouped depthwise schedule wins ({natd:.0f} vs {lowd:.0f})")

    # ---- §9/§10: model conv-op stacks, dispatched vs tuned-op-only ----
    print("\n| model | tuned stack (ms) | dispatched (ms) | speedup | winners |")
    print("|---|---|---|---|---|")
    for (name, suite) in model_ops():
        tuned_s = sum(g.cycles_to_secs(simulate_cycles(g, ops.op_plan_for(o, g)))
                      for o in suite)
        disp = [ops.decide_op(o, g) for o in suite]
        disp_s = sum(g.cycles_to_secs(c) for (_, c, _) in disp)
        wins = {}
        for (b, _, _) in disp:
            if b != backends.PAPER_TUNED:
                wins[b] = wins.get(b, 0) + 1
        # never-lose at the stack level vs the tuned op path
        check(disp_s <= tuned_s * (1 + 1e-9),
              f"{name}: dispatched stack never loses")
        print(f"| {name} | {tuned_s*1e3:.3f} | {disp_s*1e3:.3f} "
              f"| {tuned_s/disp_s:.2f}x | {wins} |")

    # ---- §7 / §10: whole-model graphs (glue + arena) ----
    print("\n| model | nodes | convs | paper (ms) | tuned (ms) | dispatched (ms) "
          "| glue share | arena (MiB) | naive (MiB) | saved |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (name, _) in graph.MODEL_GRAPHS:
        rp = graph.model_report(name, g, ops.paper_op_plan_for)
        rt = graph.model_report(name, g, ops.op_plan_for)
        rd = graph.model_report(name, g, graph.dispatch_planner)
        check(rt["total"] <= rp["total"] * (1 + 1e-9), f"{name}: tuned graph never loses")
        check(rd["total"] <= rt["total"] * (1 + 1e-9), f"{name}: dispatched graph never loses")
        check(rt["peak"] == rt["floor"], f"{name}: greedy arena reaches the liveness floor")
        check(rt["peak"] < rt["naive"], f"{name}: arena saves memory")
        print(f"| {name} | {rt['nodes']} | {rt['convs']} | {rp['total']*1e3:.3f} "
              f"| {rt['total']*1e3:.3f} | {rd['total']*1e3:.3f} "
              f"| {100*rd['glue']/rd['total']:.0f}% | {rt['peak']/2**20:.2f} "
              f"| {rt['naive']/2**20:.2f} | {100*(1-rt['peak']/rt['naive']):.0f}% |")
    rt = graph.model_report("vgg16", g, ops.op_plan_for)
    rd = graph.model_report("vgg16", g, graph.dispatch_planner)
    approx(rt["total"] * 1e3, PINNED["graph_vgg16_tuned_ms"], 0.01, "§7 VGG-16 tuned graph")
    approx(rd["total"] * 1e3, PINNED["graph_vgg16_dispatched_ms"], 0.01,
           "§7 VGG-16 dispatched graph")
    approx(graph.model_report("resnet18", g, ops.op_plan_for)["total"] * 1e3,
           PINNED["graph_resnet18_tuned_ms"], 0.01, "§7 ResNet-18 tuned graph (stride-2)")
    approx(graph.model_report("mobilenet_v1", g, ops.op_plan_for)["total"] * 1e3,
           PINNED["graph_mobilenet_tuned_ms"], 0.01, "§10 MobileNetV1 tuned graph")

    # batched dispatch: monotone, amortizing, bounded by the tuned path
    # (check(), not assert: must still gate under `python3 -O`)
    for p in [ConvProblem.multi(64, 56, 64, 3), ConvProblem.multi(16, 7, 32, 3)]:
        single = backends.dispatched_batched_seconds(p, 1, g)
        last = 0.0
        for n in (1, 2, 4, 8):
            s = backends.dispatched_batched_seconds(p, n, g)
            t = tuner.batched_seconds(p, n, g)
            if s > t * (1 + 1e-9):
                print(f"FAIL: {p.label()} n={n}: dispatch above the tuned path")
                sys.exit(1)
            if not (last < s <= n * single * (1 + 1e-9)):
                print(f"FAIL: {p.label()} n={n}: not monotone/amortizing")
                sys.exit(1)
            last = s
    print("ok: batched dispatch monotone, amortizing, never above tuned")

    print("\nALL BACKEND + OP CHECKS PASSED")


if __name__ == "__main__":
    main()
