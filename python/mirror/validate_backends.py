"""Validate the backend layer's numbers, and generate the EXPERIMENTS.md
§9 table, by replaying the Rust dispatcher's arithmetic exactly:
per-problem cross-backend ranking with the paper-tuned plan as floor.

Also replays the *pinned* EXPERIMENTS.md headline tables (§3/§4 means
vs the cuDNN proxy, §5 tuned-vs-paper geomeans) so any drift between
this mirror and the documented numbers fails loudly.

Run: python3 python/mirror/validate_backends.py
"""

import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))

import backends
import tuner
from gpusim import gtx_1080ti, simulate_cycles, titan_x_maxwell
from plans import ConvProblem, paper_plan_for
from suites import (alexnet, all_cnn_layers, fig4_suite, fig5_suite,
                    googlenet_inception3a, resnet18, vgg16)


def geomean(xs):
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def check(cond, msg):
    if not cond:
        print(f"FAIL: {msg}")
        sys.exit(1)
    print(f"ok: {msg}")


def approx(got, want, tol, msg):
    check(abs(got - want) <= tol, f"{msg}: got {got:.4f}, pinned {want:.4f}")


# ---- pinned EXPERIMENTS.md values (update together with the doc) ----

PINNED = {
    # §3 / §4: paper plans vs the cuDNN proxy (means over all cases)
    "fig4_vs_cudnn_mean": 2.19,
    "fig5_vs_cudnn_mean": 1.64,
    # §5: tuned vs paper-fixed geomeans
    "tuned_fig4": 1.013,
    "tuned_fig5": 1.137,
    "tuned_cnn": 1.175,
    "tuned_fig5_titanx": 1.190,
    # §9: dispatch vs tuned-paper-only geomeans
    "dispatch_fig4": 1.042,
    "dispatch_fig5": 1.081,
    "dispatch_cnn": 1.112,
    "dispatch_fig5_titanx": 1.093,
}


def suite_speedups_tuned_vs_paper(suite, spec):
    out = []
    for p in suite:
        paper_cycles = simulate_cycles(spec, paper_plan_for(p, spec))
        tuned_cycles = simulate_cycles(spec, tuner.tuned_plan(p, spec))
        check_never = tuned_cycles <= paper_cycles * (1 + 1e-9)
        if not check_never:
            print(f"FAIL: tuner lost on {p.label()}")
            sys.exit(1)
        out.append(paper_cycles / tuned_cycles)
    return out


def suite_dispatch(suite, spec):
    rows = []
    for p in suite:
        backend, cycles, tuned_cycles = backends.decide(p, spec)
        if cycles > tuned_cycles * (1 + 1e-9):
            print(f"FAIL: dispatcher lost on {p.label()}")
            sys.exit(1)
        rows.append((p, backend, cycles, tuned_cycles))
    return rows


def dispatch_summary(name, suite, spec):
    rows = suite_dispatch(suite, spec)
    speedups = [t / c for (_, _, c, t) in rows]
    wins = {}
    for (_, b, _, _) in rows:
        if b != backends.PAPER_TUNED:
            wins[b] = wins.get(b, 0) + 1
    g = geomean(speedups)
    non_paper = sum(wins.values())
    print(f"| {name} | {non_paper}/{len(rows)} | {g:.3f}x "
          f"| {max(speedups):.2f}x | {wins} |")
    return g, rows


def main():
    g = gtx_1080ti()
    tx = titan_x_maxwell()

    # ---- §3 / §4 replay: paper plans vs the cuDNN proxy ----
    for (name, suite, pin) in [("fig4", fig4_suite(), "fig4_vs_cudnn_mean"),
                               ("fig5", fig5_suite(), "fig5_vs_cudnn_mean")]:
        speedups = []
        for p in suite:
            ours = simulate_cycles(g, paper_plan_for(p, g))
            base = simulate_cycles(g, backends.cudnn_plan(p, g))
            speedups.append(base / ours)
        check(all(s > 1.0 for s in speedups), f"{name}: ours wins every case")
        approx(sum(speedups) / len(speedups), PINNED[pin], 0.02,
               f"{name} mean vs cudnn proxy")

    # ---- §5 replay: tuned vs paper geomeans ----
    approx(geomean(suite_speedups_tuned_vs_paper(fig4_suite(), g)),
           PINNED["tuned_fig4"], 0.005, "§5 Fig.4 tuned geomean")
    approx(geomean(suite_speedups_tuned_vs_paper(fig5_suite(), g)),
           PINNED["tuned_fig5"], 0.005, "§5 Fig.5 tuned geomean")
    approx(geomean(suite_speedups_tuned_vs_paper(all_cnn_layers(), g)),
           PINNED["tuned_cnn"], 0.005, "§5 CNN tuned geomean")
    approx(geomean(suite_speedups_tuned_vs_paper(fig5_suite(), tx)),
           PINNED["tuned_fig5_titanx"], 0.005, "§5 Fig.5 Titan X tuned geomean")

    # ---- §9: the dispatcher ----
    print("\n| suite | non-paper wins | geomean | max | winners |")
    print("|---|---|---|---|---|")
    g4, _ = dispatch_summary("Fig. 4 (18 single-channel)", fig4_suite(), g)
    g5, rows5 = dispatch_summary("Fig. 5 (21 multi-channel)", fig5_suite(), g)
    gc, rowsc = dispatch_summary("CNN layers (29)", all_cnn_layers(), g)
    gt, _ = dispatch_summary("Fig. 5 on Titan X", fig5_suite(), tx)

    approx(g4, PINNED["dispatch_fig4"], 0.005, "§9 Fig.4 dispatch geomean")
    approx(g5, PINNED["dispatch_fig5"], 0.005, "§9 Fig.5 dispatch geomean")
    approx(gc, PINNED["dispatch_cnn"], 0.005, "§9 CNN dispatch geomean")
    approx(gt, PINNED["dispatch_fig5_titanx"], 0.005, "§9 Titan X dispatch geomean")

    check(max(g4, g5, gc, gt) > 1.001, "a baseline legitimately wins somewhere")

    # the regime checks the Rust tests pin
    b, _, _ = backends.decide(ConvProblem.multi(256, 56, 256, 3), g)
    check(b == "winograd", f"winograd wins the big K=3 layer (got {b})")
    b, _, _ = backends.decide(ConvProblem.multi(256, 14, 256, 1), g)
    check(b == backends.PAPER_TUNED, f"paper kernel keeps its small-map K=1 home turf (got {b})")
    for (p, b, _, _) in rows5 + rowsc:
        check_cpu = b != "cpu-reference"
        if not check_cpu:
            print(f"FAIL: cpu-reference dispatched on {p.label()}")
            sys.exit(1)
    print("ok: cpu-reference never dispatched")
    vgg_backends = {backends.decide(p, g)[0] for p in vgg16()}
    check(len(vgg_backends) > 1 and backends.PAPER_TUNED in vgg_backends,
          f"VGG-16 mixes backends per layer: {sorted(vgg_backends)}")

    # ---- §9: model conv stacks, dispatched vs tuned-paper-only ----
    print("\n| model | tuned stack (ms) | dispatched (ms) | speedup | winners |")
    print("|---|---|---|---|---|")
    for (name, suite) in [("alexnet", alexnet()), ("vgg16", vgg16()),
                          ("resnet18", resnet18()),
                          ("inception3a", googlenet_inception3a())]:
        tuned_s = sum(g.cycles_to_secs(simulate_cycles(g, tuner.tuned_plan(p, g)))
                      for p in suite)
        disp = [backends.decide(p, g) for p in suite]
        disp_s = sum(g.cycles_to_secs(c) for (_, c, _) in disp)
        wins = {}
        for (b, _, _) in disp:
            if b != backends.PAPER_TUNED:
                wins[b] = wins.get(b, 0) + 1
        check(disp_s <= tuned_s * (1 + 1e-9), f"{name}: dispatched stack never loses")
        print(f"| {name} | {tuned_s*1e3:.3f} | {disp_s*1e3:.3f} "
              f"| {tuned_s/disp_s:.2f}x | {wins} |")

    # batched dispatch: monotone, amortizing, bounded by the tuned path
    # (check(), not assert: must still gate under `python3 -O`)
    for p in [ConvProblem.multi(64, 56, 64, 3), ConvProblem.multi(16, 7, 32, 3)]:
        single = backends.dispatched_batched_seconds(p, 1, g)
        last = 0.0
        for n in (1, 2, 4, 8):
            s = backends.dispatched_batched_seconds(p, n, g)
            t = tuner.batched_seconds(p, n, g)
            if s > t * (1 + 1e-9):
                print(f"FAIL: {p.label()} n={n}: dispatch above the tuned path")
                sys.exit(1)
            if not (last < s <= n * single * (1 + 1e-9)):
                print(f"FAIL: {p.label()} n={n}: not monotone/amortizing")
                sys.exit(1)
            last = s
    print("ok: batched dispatch monotone, amortizing, never above tuned")

    print("\nALL BACKEND CHECKS PASSED")


if __name__ == "__main__":
    main()
