"""Mirror of rust/src/tuner: enumerate -> score -> top-K simulate ->
memoized plan_for, plus the batched cost helpers from plans/mod.rs."""

from gpusim import (ExecConfig, WRITEBACK_TAIL_FRACTION, occupancy_blocks,
                    simulate_cycles, simulate_pipeline_runs)
from plans import (BYTES_F32, COMPUTE_EFFICIENCY, FILTER_SPLIT,
                   LAUNCH_OVERHEAD_CYCLES, MAP_SPLIT, ceil_div, choose_single,
                   d1_bytes, d2_bytes, multi_choice, paper_plan_for,
                   single_choice, single_plan_with_choice, single_recipe,
                   stride_plan_and_choice, stride_plan_with_choice,
                   stride_recipe, working_set_bytes)

TOP_K = 8
MAX_ROUNDS = 4_000_000
SEGMENT_SWEEP = [32, 64, 96, 128]
WX_SWEEP = [32, 64, 96, 128, 160, 192, 224, 256]


def distinct_divisions(n):
    out = []
    d = 1
    while d <= n:
        q = ceil_div(n, d)
        out.append(d)
        d = max(d + 1, (n - 1) // (q - 1) + 1) if q > 1 else n + 1
    return out


def divisors(n):
    out = []
    d = 1
    while d * d <= n:
        if n % d == 0:
            out.append(d)
            if d != n // d:
                out.append(n // d)
        d += 1
    return sorted(out)


# PlanParams: ("single", method, p, q) | ("multi", s, wx, mp)

def enumerate_params(p, spec):
    assert p.valid()
    if p.is_single_channel():
        budget = spec.shared_mem_bytes
        out = []
        for pp in distinct_divisions(p.wy):
            if d1_bytes(p, spec, pp) <= budget:
                out.append(("single", FILTER_SPLIT, pp, 1))
        for q in distinct_divisions(p.m):
            if d2_bytes(p, spec, q) <= budget:
                out.append(("single", MAP_SPLIT, 1, q))
        fallback = ("single", FILTER_SPLIT, 1, 1)
        if fallback not in out:
            out.append(fallback)
        return out
    half = spec.shared_mem_bytes // 2
    out_px = p.oy() * p.ox()
    map_px = ceil_div(out_px, 32) * 32
    wx_opts = [w for w in WX_SWEEP if w <= max(map_px, 32)]
    m_opts = divisors(p.m)
    out = []
    for s in SEGMENT_SWEEP:
        for wx in wx_opts:
            for mp in m_opts:
                if working_set_bytes(s, wx, mp, p.k) <= half:
                    out.append(("multi", s, wx, mp))
    return out


def _exec_config(sms, threads):
    return ExecConfig(sms, threads, COMPUTE_EFFICIENCY, LAUNCH_OVERHEAD_CYCLES)


def _writeback(spec, p):
    return WRITEBACK_TAIL_FRACTION * (p.out_elems() * BYTES_F32) / spec.bytes_per_cycle()


def score(p, spec, params):
    if params[0] == "single":
        _, method, pp, q = params
        c = single_choice(p, spec, method, pp, q)
        first, tail, sms, threads, _ = single_recipe(p, spec, c)
        runs = [(first, 1)]
        if tail is not None:
            if tail[1] > MAX_ROUNDS:
                return None
            runs.append(tail)
        t, _ = simulate_pipeline_runs(spec, _exec_config(sms, threads), runs)
        return t + _writeback(spec, p)
    _, s, wx, mp = params
    c = multi_choice(p, spec, s, wx, mp)
    rnd, count, sms, threads = stride_recipe(p, spec, c)
    if count > MAX_ROUNDS:
        return None
    t, _ = simulate_pipeline_runs(spec, _exec_config(sms, threads), [(rnd, count)])
    return t + _writeback(spec, p)


def build_plan(p, spec, params):
    if params[0] == "single":
        _, method, pp, q = params
        return single_plan_with_choice(p, spec, single_choice(p, spec, method, pp, q))
    _, s, wx, mp = params
    return stride_plan_with_choice(p, spec, multi_choice(p, spec, s, wx, mp))


def is_legal(spec, plan):
    if plan.smem_bytes_per_sm > spec.shared_mem_bytes:
        return False
    if plan.sms_active < 1 or plan.sms_active > spec.sm_count:
        return False
    blocks_needed = max(ceil_div(plan.threads_per_sm, 512), 1)
    blocks = occupancy_blocks(spec, 512, 64, plan.smem_bytes_per_sm // blocks_needed)
    return blocks >= blocks_needed


def paper_params(p, spec):
    if p.is_single_channel():
        c = choose_single(p, spec)
        return single_plan_with_choice(p, spec, c), ("single", c.method, c.p, c.q)
    plan, c = stride_plan_and_choice(p, spec)
    return plan, ("multi", c.s_bytes, c.wx_prime, c.m_prime)


def tune(p, spec):
    paper_plan, paper = paper_params(p, spec)
    paper_cycles = simulate_cycles(spec, paper_plan)
    scored = []
    for cand in enumerate_params(p, spec):
        s = score(p, spec, cand)
        if s is not None:
            scored.append((s, cand))
    scored.sort(key=lambda x: x[0])

    best = (paper_cycles, paper)
    checked = 0
    for _, params in scored:
        if checked == TOP_K:
            break
        plan = build_plan(p, spec, params)
        if not is_legal(spec, plan):
            continue
        checked += 1
        cycles = simulate_cycles(spec, plan)
        if cycles < best[0]:
            best = (cycles, params)
    return best  # (tuned_cycles, params), paper_cycles available via paper_plan


_CACHE = {}


def tuned_plan(p, spec):
    key = (p, spec.name)
    if key not in _CACHE:
        _CACHE[key] = tune(p, spec)[1]
    return build_plan(p, spec, _CACHE[key])


def plan_for(p, spec):
    return tuned_plan(p, spec)


# ---- plans/mod.rs batched helpers ----

def batched_plan_for(problem, n, spec):
    return plan_for(problem, spec).batched(n)


def batched_cycles(problem, n, spec):
    return simulate_cycles(spec, batched_plan_for(problem, n, spec))


def batched_seconds(problem, n, spec):
    return spec.cycles_to_secs(batched_cycles(problem, n, spec))
