"""Mirror of rust/src/tuner: enumerate -> score -> top-K simulate ->
memoized plan_for, plus the batched cost helpers from plans/mod.rs."""

from gpusim import (CYCLIC, ExecConfig, ORDERED, TILEWISE, occupancy_blocks,
                    simulate_cycles, simulate_pipeline_runs,
                    writeback_tail_cycles)
from plans import (BYTES_F32, COMPUTE_EFFICIENCY, FILTER_SPLIT,
                   LAUNCH_OVERHEAD_CYCLES, MAP_SPLIT, ceil_div, choose_single,
                   d1_bytes, d2_bytes, multi_choice, paper_plan_for,
                   single_choice, single_plan_with_choice, single_recipe,
                   single_stage_bytes, staged_working_set_bytes,
                   stride_plan_and_choice, stride_plan_with_choice,
                   stride_recipe, working_set_bytes)

TOP_K = 8
MAX_ROUNDS = 4_000_000
SEGMENT_SWEEP = [32, 64, 96, 128]
WX_SWEEP = [32, 64, 96, 128, 160, 192, 224, 256]

# (stages, loading) variants the tuner crosses with every geometry.
# Tilewise serializes its loads per warp, so stages > 2 only spend smem
# without amortizing latency — the sweep skips those dominated points.
STAGED_VARIANTS = [(2, CYCLIC), (3, CYCLIC), (4, CYCLIC),
                   (2, TILEWISE),
                   (2, ORDERED), (3, ORDERED), (4, ORDERED)]


def distinct_divisions(n):
    out = []
    d = 1
    while d <= n:
        q = ceil_div(n, d)
        out.append(d)
        d = max(d + 1, (n - 1) // (q - 1) + 1) if q > 1 else n + 1
    return out


def divisors(n):
    out = []
    d = 1
    while d * d <= n:
        if n % d == 0:
            out.append(d)
            if d != n // d:
                out.append(n // d)
        d += 1
    return sorted(out)


# PlanParams: ("single", method, p, q, stages, loading)
#           | ("multi", s, wx, mp, stages, loading)

def enumerate_params(p, spec):
    assert p.valid()
    if p.is_single_channel():
        budget = spec.shared_mem_bytes
        bases = []
        for pp in distinct_divisions(p.wy):
            if d1_bytes(p, spec, pp) <= budget:
                bases.append((FILTER_SPLIT, pp, 1, d1_bytes(p, spec, pp)))
        for q in distinct_divisions(p.m):
            if d2_bytes(p, spec, q) <= budget:
                bases.append((MAP_SPLIT, 1, q, d2_bytes(p, spec, q)))
        if not any(m == FILTER_SPLIT and pp == 1 and q == 1
                   for (m, pp, q, _) in bases):
            bases.append((FILTER_SPLIT, 1, 1, d1_bytes(p, spec, 1)))
        out = []
        for (method, pp, q, d) in bases:
            stage = single_stage_bytes(p, spec, method, pp, q)
            for (st, ld) in STAGED_VARIANTS:
                if d + (st - 2) * stage <= budget:
                    out.append(("single", method, pp, q, st, ld))
        return out
    half = spec.shared_mem_bytes // 2
    out_px = p.oy() * p.ox()
    map_px = ceil_div(out_px, 32) * 32
    wx_opts = [w for w in WX_SWEEP if w <= max(map_px, 32)]
    m_opts = divisors(p.m)
    out = []
    for s in SEGMENT_SWEEP:
        for wx in wx_opts:
            for mp in m_opts:
                for (st, ld) in STAGED_VARIANTS:
                    if staged_working_set_bytes(s, wx, mp, p.k, st) <= half:
                        out.append(("multi", s, wx, mp, st, ld))
    return out


def _exec_config(sms, threads, stages, loading):
    return ExecConfig(sms, threads, COMPUTE_EFFICIENCY, LAUNCH_OVERHEAD_CYCLES,
                      stages, loading)


def _writeback(spec, p, pipe_total, loads, stages):
    """Charged writeback, matching simulate_parts: max(staged tail,
    DRAM bus-floor excess) so score stays bit-identical to simulate."""
    out = p.out_elems() * BYTES_F32
    tail = writeback_tail_cycles(spec, out, stages)
    floor = (loads + out) / spec.bytes_per_cycle()
    return max(tail, floor - pipe_total)


def score(p, spec, params):
    if params[0] == "single":
        _, method, pp, q, st, ld = params
        c = single_choice(p, spec, method, pp, q)
        first, tail, sms, threads, _, _, _ = single_recipe(p, spec, c)
        runs = [(first, 1)]
        if tail is not None:
            if tail[1] > MAX_ROUNDS:
                return None
            runs.append(tail)
        t, _ = simulate_pipeline_runs(spec, _exec_config(sms, threads, st, ld), runs)
        loads = sum(r.load_bytes * n for (r, n) in runs) * sms
        return t + _writeback(spec, p, t, loads, st)
    _, s, wx, mp, st, ld = params
    c = multi_choice(p, spec, s, wx, mp)
    rnd, count, sms, threads, _ = stride_recipe(p, spec, c)
    if count > MAX_ROUNDS:
        return None
    t, _ = simulate_pipeline_runs(spec, _exec_config(sms, threads, st, ld),
                                  [(rnd, count)])
    loads = rnd.load_bytes * count * sms
    return t + _writeback(spec, p, t, loads, st)


def build_plan(p, spec, params):
    if params[0] == "single":
        _, method, pp, q, st, ld = params
        base = single_plan_with_choice(p, spec, single_choice(p, spec, method, pp, q))
        return base.staged(st, ld)
    _, s, wx, mp, st, ld = params
    base = stride_plan_with_choice(p, spec, multi_choice(p, spec, s, wx, mp))
    return base.staged(st, ld)


def is_legal(spec, plan):
    if plan.smem_bytes_per_sm > spec.shared_mem_bytes:
        return False
    if plan.sms_active < 1 or plan.sms_active > spec.sm_count:
        return False
    blocks_needed = max(ceil_div(plan.threads_per_sm, 512), 1)
    blocks = occupancy_blocks(spec, 512, 64, plan.smem_bytes_per_sm // blocks_needed)
    return blocks >= blocks_needed


def paper_params(p, spec):
    if p.is_single_channel():
        c = choose_single(p, spec)
        return single_plan_with_choice(p, spec, c), \
            ("single", c.method, c.p, c.q, 2, CYCLIC)
    plan, c = stride_plan_and_choice(p, spec)
    return plan, ("multi", c.s_bytes, c.wx_prime, c.m_prime, 2, CYCLIC)


def tune(p, spec, staged=True):
    """Tune over the full (geometry x stages x loading) space; with
    staged=False restrict to the depth-2 cyclic subspace (the pre-
    multi-stage plan space, used as the ablation floor)."""
    paper_plan, paper = paper_params(p, spec)
    paper_cycles = simulate_cycles(spec, paper_plan)
    scored = []
    for cand in enumerate_params(p, spec):
        if not staged and (cand[4] != 2 or cand[5] != CYCLIC):
            continue
        s = score(p, spec, cand)
        if s is not None:
            scored.append((s, cand))
    scored.sort(key=lambda x: x[0])

    best = (paper_cycles, paper)
    checked = 0
    for _, params in scored:
        if checked == TOP_K:
            break
        plan = build_plan(p, spec, params)
        if not is_legal(spec, plan):
            continue
        checked += 1
        cycles = simulate_cycles(spec, plan)
        if cycles < best[0]:
            best = (cycles, params)
    return best  # (tuned_cycles, params), paper_cycles available via paper_plan


_CACHE = {}


def tuned_params(p, spec):
    """Memoized unit-tuned PlanParams (mirror of tuner::tuned().params)."""
    key = (p, spec.name)
    if key not in _CACHE:
        _CACHE[key] = tune(p, spec)[1]
    return _CACHE[key]


def tuned_plan(p, spec):
    return build_plan(p, spec, tuned_params(p, spec))


def depth2_tuned_plan(p, spec):
    """Best plan of the pre-multi-stage (depth-2, cyclic) space — the
    floor the multi-stage gate compares against."""
    key = (p, spec.name, "depth2")
    if key not in _CACHE:
        _CACHE[key] = tune(p, spec, staged=False)[1]
    return build_plan(p, spec, _CACHE[key])


def plan_for(p, spec):
    return tuned_plan(p, spec)


# ---- plans/mod.rs batched helpers ----

def batched_plan_for(problem, n, spec):
    return plan_for(problem, spec).batched(n)


def batched_cycles(problem, n, spec):
    return simulate_cycles(spec, batched_plan_for(problem, n, spec))


def batched_seconds(problem, n, spec):
    return spec.cycles_to_secs(batched_cycles(problem, n, spec))
