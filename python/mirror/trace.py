"""Mirror of rust/src/trace: the roofline report (report.rs — §12 rows
for the Fig.4/Fig.5 suites and the five models) and the span-tree
validator (span.rs::validate at the Chrome-trace JSON level), so CI can
gate both the pinned numbers and any exported trace file without a rust
toolchain."""

import json

import backends
import graph as graphmod
import ops as opsmod
import suites
from gpusim import plan_dram_load_bytes as dram_load_bytes
from gpusim import simulate_parts

EPS = 1e-9  # span.rs::EPS


# ---- roofline counters (mirror of trace/roofline.rs, headline set) ----

def simulate_result(spec, plan):
    """Mirror of gpusim::simulate_detailed's headline fields: the stall
    rule reads the PRE-writeback pipeline total, exactly as
    PipelineResult::bottleneck does, and the row is memory-bound when
    the DRAM bus floor binds the writeback charge.

    bw_frac_charged counts the bytes the timing model charges (loads +
    charged writeback); bw_frac_total counts ALL traffic.  Both are
    <= 1.0 by construction since the bus floor entered the timing."""
    pipe_total, stall, tail, wb = simulate_parts(spec, plan)
    cycles = pipe_total + wb
    seconds = spec.cycles_to_secs(cycles)
    flops = 2.0 * plan.total_fma
    loads = dram_load_bytes(plan)
    charged = loads + wb * spec.bytes_per_cycle()
    return {
        "cycles": cycles,
        "seconds": seconds,
        "gflops": flops / seconds / 1e9,
        "efficiency": flops / seconds / spec.peak_flops(),
        "dram_load_bytes": loads,
        "fma_per_byte": plan.total_fma / max(loads, 1.0),
        "bw_gb_s": (loads + plan.output_bytes) / seconds / 1e9,
        "bw_charged_gb_s": charged / seconds / 1e9,
        "bottleneck": "memory" if (stall > 0.05 * pipe_total or wb > tail)
        else "compute",
    }


# ---- §12 report rows (mirror of trace/report.rs) ----

def plan_tag(plan):
    """The stages/loading column: e.g. '2/cyc', '4/ord'."""
    from gpusim import LOADING_TAGS
    return f"{plan.stages}/{LOADING_TAGS[plan.loading]}"


def problem_row(p, spec):
    name = backends.decide(p, spec)[0]
    plan = backends.backend_plan(name, p, spec)
    r = simulate_result(spec, plan)
    return {
        "label": p.label(),
        "backend": name,
        "staging": plan_tag(plan),
        "fma_per_byte": r["fma_per_byte"],
        "gflops": r["gflops"],
        "flops_pct": 100.0 * r["efficiency"],
        "bw_charged_pct": 100.0 * r["bw_charged_gb_s"] / spec.bandwidth_gb_s,
        "bw_total_pct": 100.0 * r["bw_gb_s"] / spec.bandwidth_gb_s,
        "bottleneck": r["bottleneck"],
    }


def fig4_rows(spec):
    return [problem_row(p, spec) for p in suites.fig4_suite()]


def fig5_rows(spec):
    return [problem_row(p, spec) for p in suites.fig5_suite()]


def model_rows(spec):
    rows = []
    for (name, build) in graphmod.MODEL_GRAPHS:
        g = build()
        fma = conv_loads = conv_stores = conv_charged = glue = 0.0
        for n in g.nodes:
            if n.kind == "conv":
                plan = opsmod.dispatch_op_plan(n.conv, spec)
                _, _, _, wb = simulate_parts(spec, plan)
                fma += plan.total_fma
                conv_loads += dram_load_bytes(plan)
                conv_stores += plan.output_bytes
                conv_charged += dram_load_bytes(plan) + wb * spec.bytes_per_cycle()
            else:
                glue += graphmod.glue_bytes(g, n)
        secs = graphmod.execute(g, spec, graphmod.dispatch_planner)[0]
        flops_frac = 2.0 * fma / secs / spec.peak_flops()
        bw_charged = (conv_charged + glue) / secs / 1e9 / spec.bandwidth_gb_s
        bw_total = (conv_loads + conv_stores + glue) / secs / 1e9 / spec.bandwidth_gb_s
        rows.append({
            "label": name,
            "backend": "dispatched",
            "staging": "-",
            "fma_per_byte": fma / max(conv_loads, 1.0),
            "gflops": 2.0 * fma / secs / 1e9,
            "flops_pct": 100.0 * flops_frac,
            "bw_charged_pct": 100.0 * bw_charged,
            "bw_total_pct": 100.0 * bw_total,
            "bottleneck": "memory" if bw_total >= flops_frac else "compute",
        })
    return rows


# ---- Chrome-trace span-tree validation (mirror of span.rs::validate) ----

def validate_chrome(doc):
    """Validate a parsed Chrome-trace document (the `--trace-out`
    format): well-nested per lane, parent containment by span_id, per-
    (lane, name) monotone virtual time, named lanes, causes on rejects.
    Raises AssertionError with a message on the first violation."""
    events = doc["traceEvents"]
    lanes = {}
    for ev in events:
        if ev.get("ph") == "M":
            assert ev["name"] == "thread_name", ev
            lanes[ev["tid"]] = ev["args"]["name"]

    spans = [e for e in events if e.get("ph") == "X"]
    instants = [e for e in events if e.get("ph") == "i"]
    for ev in spans + instants:
        assert ev["tid"] in lanes, f"event on unnamed lane: {ev}"
        assert ev["ts"] >= -EPS, f"negative virtual time: {ev}"
    for ev in spans:
        assert ev["dur"] >= -EPS, f"negative duration: {ev}"

    # unique ids + parent containment (span.rs pass 1–2)
    by_id = {}
    for ev in spans:
        sid = ev["args"]["span_id"]
        assert sid not in by_id, f"duplicate span id {sid}"
        by_id[sid] = ev
    for ev in spans:
        pid = ev["args"].get("parent_id")
        if pid is None:
            continue
        parent = by_id.get(pid)
        assert parent is not None, f"dangling parent {pid}"
        assert parent["tid"] == ev["tid"], f"cross-lane parent: {ev}"
        assert parent["ts"] - EPS <= ev["ts"], f"child starts before parent: {ev}"
        assert (ev["ts"] + ev["dur"]
                <= parent["ts"] + parent["dur"] + EPS), f"child outlives parent: {ev}"

    # per-lane nested-or-disjoint (span.rs pass 3): sweep with a stack
    per_lane = {}
    for ev in spans:
        per_lane.setdefault(ev["tid"], []).append((ev["ts"], ev["ts"] + ev["dur"]))
    for tid, iv in per_lane.items():
        iv.sort(key=lambda ab: (ab[0], -ab[1]))
        stack = []
        for (a, b) in iv:
            while stack and stack[-1] <= a + EPS:
                stack.pop()
            assert not stack or b <= stack[-1] + EPS, \
                f"lane {lanes[tid]}: [{a}, {b}] straddles [.., {stack[-1]}]"
            stack.append(b)

    # per-(lane, name) monotone emission (span.rs pass 4), spans and
    # instants as separate streams — relies on `traceEvents` preserving
    # emission order, which the exporter guarantees
    last = {}
    for ev in spans:
        key = ("X", ev["tid"], ev["name"])
        assert last.get(key, -1.0) <= ev["ts"] + EPS, f"non-monotone span: {ev}"
        last[key] = ev["ts"]
    for ev in instants:
        key = ("i", ev["tid"], ev["name"])
        assert last.get(key, -1.0) <= ev["ts"] + EPS, f"non-monotone instant: {ev}"
        last[key] = ev["ts"]

    # fleet semantics: rejects carry a cause, requests carry an execute
    for ev in instants:
        if ev["name"] == "reject":
            assert ev["args"].get("cause") in ("memory", "queue_full"), ev
    lane_names = {tid: nm for tid, nm in lanes.items()}
    executes = {ev["tid"] for ev in spans if ev["name"] == "execute"}
    for ev in spans:
        if ev["name"] == "request":
            assert lane_names[ev["tid"]].startswith("req:"), ev
            assert ev["tid"] in executes, \
                f"request on {lane_names[ev['tid']]} has no execute child"
    return len(spans), len(instants)


def validate_chrome_file(path):
    with open(path) as f:
        return validate_chrome(json.load(f))
