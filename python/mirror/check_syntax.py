r"""Delimiter-balance scan for the rust/ tree: catches the class of
errors a toolchain-free edit can introduce (unbalanced braces/brackets/
parens, unterminated strings or comments) without rustc.  This is NOT a
parser — it tokenizes just enough of Rust's lexical grammar to know
which bytes are code:

* line comments (//...) and nested block comments (/* /* */ */)
* string literals with escapes, byte strings (b"..")
* raw strings r"..", r#".."#, br#".."# with any hash depth
* char literals ('x', '\n', '\u{1F600}') vs lifetimes (&'a, <'de>)

It also flags comment-looking lines that start with a single `/` (a
`//` that lost a slash parses as division and can silently change
numerics); genuine `/ x`-style expression continuations are exempt.

Run as `python3 check_syntax.py [root]` (default: the repo's rust/
directory); exits non-zero listing every unbalanced file.  CI runs it
alongside the mirror validators so a syntax-broken .rs file fails fast
even in jobs that never invoke cargo.
"""

import sys
from pathlib import Path

OPEN = {"(": ")", "[": "]", "{": "}"}
CLOSE = {")": "(", "]": "[", "}": "{"}


def strip_code(text):
    """Yield (line_number, char) for every char that is real code —
    comments, strings and char literals are skipped entirely."""
    i, n, line = 0, len(text), 1
    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if c == "\n":
            line += 1
            i += 1
        elif c == "/" and nxt == "/":
            while i < n and text[i] != "\n":
                i += 1
        elif c == "/" and nxt == "*":
            depth, i = 1, i + 2
            while i < n and depth:
                if text[i] == "\n":
                    line += 1
                if text[i : i + 2] == "/*":
                    depth, i = depth + 1, i + 2
                elif text[i : i + 2] == "*/":
                    depth, i = depth - 1, i + 2
                else:
                    i += 1
            if depth:
                raise SyntaxError(f"line {line}: unterminated block comment")
        elif c in "rb" and _raw_start(text, i):
            j = i
            while text[j] in "rb":
                j += 1
            hashes = 0
            while text[j] == "#":
                hashes, j = hashes + 1, j + 1
            close = '"' + "#" * hashes
            end = text.find(close, j + 1)
            if end < 0:
                raise SyntaxError(f"line {line}: unterminated raw string")
            line += text.count("\n", i, end)
            i = end + len(close)
        elif c == '"' or (c == "b" and nxt == '"'):
            i += 2 if c == "b" else 1
            while i < n:
                if text[i] == "\\":
                    i += 2
                elif text[i] == '"':
                    i += 1
                    break
                else:
                    if text[i] == "\n":
                        line += 1
                    i += 1
            else:
                raise SyntaxError(f"line {line}: unterminated string")
        elif c == "'":
            # lifetime ('a, 'static) or char literal?  A char literal
            # always has a closing quote within a few chars; a lifetime
            # never does.  Escapes and \u{..} make "a few" up to 10.
            j = i + 1
            if j < n and text[j] == "\\":
                k = text.find("'", j + 1)
                if k < 0:
                    raise SyntaxError(f"line {line}: unterminated char literal")
                i = k + 1
            elif j + 1 < n and text[j + 1] == "'":
                i = j + 2  # plain 'x'
            else:
                yield line, c  # lifetime tick: harmless, not a delimiter
                i += 1
        else:
            yield line, c
            i += 1


def _raw_start(text, i):
    """True when text[i:] starts a raw/byte-raw string literal (r", r#",
    br", rb#"...), not an identifier like `radius`."""
    j = i
    seen = set()
    while j < len(text) and text[j] in "rb" and text[j] not in seen:
        seen.add(text[j])
        j += 1
    if "r" not in seen:
        return False
    while j < len(text) and text[j] == "#":
        j += 1
    return j < len(text) and text[j] == '"'


def comment_typo_lines(text):
    """Line numbers of code lines that look like a comment that lost a
    slash: real *code* (per the tokenizer — so `//` and `/* */` bodies
    never trigger) starting with a single `/`, in a position where no
    binary `/` could continue the previous expression (the previous
    code line ended with `;`, `{` or `}`, or there is none).  Legal
    division continuations like

        let exposed = latency_exposure(...)
            / depth;

    stay unflagged because their previous code line ends mid-expression
    (`)`, an identifier, an operator...)."""
    code = {}
    for line, c in strip_code(text):
        code[line] = code.get(line, "") + c
    flagged = []
    prev_end = ""  # last char of the previous non-blank code line
    for line in sorted(code):
        s = code[line].strip()
        if not s:
            continue
        if s.startswith("/") and not s.startswith("//") and prev_end in ("", ";", "{", "}"):
            flagged.append(line)
        prev_end = s[-1]
    return flagged


def check_file(path):
    """Return a list of error strings (empty = balanced)."""
    text = path.read_text()
    stack = []  # (line, open_char)
    errors = []
    try:
        for line, c in strip_code(text):
            if c in OPEN:
                stack.append((line, c))
            elif c in CLOSE:
                if not stack:
                    errors.append(f"line {line}: unmatched {c!r}")
                    break
                oline, o = stack.pop()
                if OPEN[o] != c:
                    errors.append(f"line {line}: {c!r} closes {o!r} from line {oline}")
                    break
        for ln in comment_typo_lines(text):
            errors.append(f"line {ln}: comment-looking line starts with a single '/'")
    except SyntaxError as e:
        errors.append(str(e))
    if not errors:
        for oline, o in stack:
            errors.append(f"line {oline}: unclosed {o!r}")
    return errors


def main(argv):
    root = Path(argv[1]) if len(argv) > 1 else Path(__file__).resolve().parents[2] / "rust"
    files = sorted(root.rglob("*.rs")) if root.is_dir() else [root]
    if not files:
        print(f"check_syntax: no .rs files under {root}", file=sys.stderr)
        return 2
    bad = 0
    for f in files:
        errors = check_file(f)
        for e in errors:
            print(f"{f}: {e}", file=sys.stderr)
        bad += bool(errors)
    print(f"check_syntax: {len(files)} files, {bad} unbalanced")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
