"""L1 Pallas kernel — implicit-GEMM baseline (cuDNN-proxy numerics).

cuDNN's memory-efficient algorithm (Implicit-GEMM, [12] in the paper)
never materializes the im2col matrix in global memory: each threadblock
builds its patch sub-matrix in shared memory and multiplies it against a
filter sub-matrix.  This kernel is the same idea on the TPU model — the
patch block is materialized *in VMEM inside a grid step* (never in HBM)
and consumed by one MXU-shaped matmul:

  grid = (M/m_blk, C/c_seg)   (segment axis innermost, accumulating)
  step: P = im2col(img_blk)            (c_seg*K*K, Oy*Ox)  in VMEM
        out += F[m_blk, c_seg*K*K] @ P

It is the numerics counterpart of ``rust/src/baselines/cudnn_proxy.rs``
(which models its *timing*): both sides describe the same schedule, so
the speedup claims compare like against like.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv2d_im2col"]


def _kernel(img_ref, flt_ref, out_ref, *, k: int, oy: int, ox: int):
    """One grid step: im2col the segment in VMEM, then a single GEMM."""
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    img = img_ref[...]
    flt = flt_ref[...]
    m_blk, c_seg = flt.shape[0], flt.shape[1]
    # Materialize the patch matrix for this channel segment in VMEM —
    # the shared-memory staging buffer of Implicit-GEMM.
    rows = []
    for ch in range(c_seg):
        for i in range(k):
            for j in range(k):
                rows.append(jax.lax.slice(img, (ch, i, j), (ch + 1, i + oy, j + ox)).reshape(oy * ox))
    patches = jnp.stack(rows).astype(jnp.float32)  # (c_seg*k*k, oy*ox)
    a = flt.reshape(m_blk, c_seg * k * k).astype(jnp.float32)
    acc = jax.lax.dot(a, patches, precision=jax.lax.Precision.HIGHEST)
    out_ref[...] = out_ref[...] + acc.reshape(m_blk, oy, ox).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m_blk", "c_seg"))
def _conv2d_im2col_tiled(image, filters, m_blk: int, c_seg: int):
    c, wy, wx = image.shape
    m, _, k, _ = filters.shape
    oy, ox = wy - k + 1, wx - k + 1
    grid = (m // m_blk, c // c_seg)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, oy=oy, ox=ox),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c_seg, wy, wx), lambda mi, s: (s, 0, 0)),
            pl.BlockSpec((m_blk, c_seg, k, k), lambda mi, s: (mi, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m_blk, oy, ox), lambda mi, s: (mi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, oy, ox), image.dtype),
        interpret=True,
    )(image, filters)


def conv2d_im2col(image: jax.Array, filters: jax.Array,
                  m_blk: int | None = None, c_seg: int | None = None) -> jax.Array:
    """Multi-channel convolution (eq. 1) via the Implicit-GEMM baseline.

    Accepts single-channel operands too (image (Wy,Wx), filters (M,K,K))
    by lifting them to C=1.
    """
    if image.ndim == 2:
        image = image[None]
        filters = filters[:, None]
    c, wy, wx = image.shape
    m, c2, k, _ = filters.shape
    assert c == c2, "channel mismatch"
    if m_blk is None:
        m_blk = m if m <= 64 else next(d for d in range(64, 0, -1) if m % d == 0)
    if c_seg is None:
        c_seg = 1 if k > 1 else min(8, c)
        while c % c_seg:
            c_seg -= 1
    if m % m_blk or c % c_seg:
        raise ValueError(f"blocks must divide: M={m}%%{m_blk}, C={c}%%{c_seg}")
    return _conv2d_im2col_tiled(image, filters, m_blk, c_seg)
