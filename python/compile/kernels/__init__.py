"""L1 Pallas kernels and their pure-jnp oracle.

Exports:
  conv2d_single  — §3.1 single-channel kernel (P/Q-tiled)
  conv2d_multi   — §3.2 stride-fixed block multi-channel kernel
  conv2d_im2col  — Implicit-GEMM baseline (cuDNN-proxy numerics)
  conv2d_winograd— Winograd F(2x2,3x3) baseline (§1 category 3)
  conv2d_fft     — FFT baseline, L2-level (§1 category 2)
  ref            — reference oracles (eq. 1 / eq. 2)
"""

from . import ref
from .single_channel import conv2d_single, choose_single_tiles
from .multi_channel import conv2d_multi, choose_multi_tiles
from .im2col_gemm import conv2d_im2col
from .winograd import conv2d_winograd
from .fft_conv import conv2d_fft

__all__ = [
    "ref",
    "conv2d_single",
    "conv2d_multi",
    "conv2d_im2col",
    "conv2d_winograd",
    "conv2d_fft",
    "choose_single_tiles",
    "choose_multi_tiles",
]
