"""Pure-jnp reference oracles for the paper's convolution definitions.

These implement equations (1) and (2) of the paper verbatim (valid
cross-correlation, no padding, stride 1) and are the single source of
truth every Pallas kernel is verified against by pytest/hypothesis.

Shapes follow the paper's notation:

  single-channel (eq. 2):
      image   I : (Wy, Wx)            float
      filters F : (M, K, K)
      output  O : (M, Oy, Ox)         Oy = Wy-K+1, Ox = Wx-K+1

  multi-channel (eq. 1):
      image   I : (C, Wy, Wx)
      filters F : (M, C, K, K)
      output  O : (M, Oy, Ox)

Two independent implementations are provided for each case: a direct
loop-free shift-and-add form, and an ``lax.conv_general_dilated`` form.
Tests cross-check the two, so a bug in one cannot silently become the
oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "conv2d_single_ref",
    "conv2d_multi_ref",
    "conv2d_single_lax",
    "conv2d_multi_lax",
    "im2col_matrix",
    "conv2d_multi_im2col_ref",
    "out_shape_single",
    "out_shape_multi",
]


def out_shape_single(image_shape, filters_shape):
    """Output shape (M, Oy, Ox) for eq. (2) operands."""
    (wy, wx) = image_shape
    (m, k, k2) = filters_shape
    assert k == k2, "filters must be square"
    return (m, wy - k + 1, wx - k + 1)


def out_shape_multi(image_shape, filters_shape):
    """Output shape (M, Oy, Ox) for eq. (1) operands."""
    (c, wy, wx) = image_shape
    (m, c2, k, k2) = filters_shape
    assert c == c2, "channel mismatch"
    assert k == k2, "filters must be square"
    return (m, wy - k + 1, wx - k + 1)


def conv2d_single_ref(image: jax.Array, filters: jax.Array) -> jax.Array:
    """Eq. (2): O^m(x,y) = sum_{i,j} I(x+i, y+j) * F^m(i,j).

    Shift-and-add form: for each (i, j) filter tap, slice the aligned
    (Oy, Ox) window of the image and scale it by the tap, broadcast over
    the M filter dimension.
    """
    wy, wx = image.shape
    m, k, _ = filters.shape
    oy, ox = wy - k + 1, wx - k + 1
    acc = jnp.zeros((m, oy, ox), dtype=jnp.promote_types(image.dtype, jnp.float32))
    for i in range(k):
        for j in range(k):
            win = lax.slice(image, (i, j), (i + oy, j + ox))
            acc = acc + win[None, :, :].astype(acc.dtype) * filters[:, i, j][:, None, None].astype(acc.dtype)
    return acc.astype(image.dtype)


def conv2d_multi_ref(image: jax.Array, filters: jax.Array) -> jax.Array:
    """Eq. (1): O^m(x,y) = sum_ch sum_{i,j} I^ch(x+i,y+j) * F^{ch,m}(i,j).

    Shift-and-add with a channel contraction per tap: each (i, j) tap
    contributes  filters[:, :, i, j] @ image[:, i:i+Oy, j:j+Ox]  which is
    an (M, C) x (C, Oy*Ox) matmul.
    """
    c, wy, wx = image.shape
    m, c2, k, _ = filters.shape
    assert c == c2
    oy, ox = wy - k + 1, wx - k + 1
    acc = jnp.zeros((m, oy * ox), dtype=jnp.promote_types(image.dtype, jnp.float32))
    for i in range(k):
        for j in range(k):
            win = lax.slice(image, (0, i, j), (c, i + oy, j + ox))
            acc = acc + filters[:, :, i, j].astype(acc.dtype) @ win.reshape(c, oy * ox).astype(acc.dtype)
    return acc.reshape(m, oy, ox).astype(image.dtype)


def conv2d_single_lax(image: jax.Array, filters: jax.Array) -> jax.Array:
    """Same as :func:`conv2d_single_ref`, via lax.conv_general_dilated."""
    return conv2d_multi_lax(image[None, :, :], filters[:, None, :, :])


def conv2d_multi_lax(image: jax.Array, filters: jax.Array) -> jax.Array:
    """Same as :func:`conv2d_multi_ref`, via lax.conv_general_dilated.

    The paper's operator is cross-correlation (no filter flip), which is
    exactly XLA's convolution with identity dimension permutations.
    """
    lhs = image[None]  # NCHW, batch of 1
    out = lax.conv_general_dilated(
        lhs.astype(jnp.float32),
        filters.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0].astype(image.dtype)


def im2col_matrix(image: jax.Array, k: int) -> jax.Array:
    """Materialized im2col patch matrix, (C*K*K, Oy*Ox).

    Row order is (ch, i, j) — the filter-memory layout of Fig. 1(b) — so
    that ``filters.reshape(M, C*K*K) @ im2col_matrix(image, K)`` computes
    eq. (1). Used by the explicit-GEMM baseline and its tests.
    """
    c, wy, wx = image.shape
    oy, ox = wy - k + 1, wx - k + 1
    rows = []
    for ch in range(c):
        for i in range(k):
            for j in range(k):
                rows.append(lax.slice(image, (ch, i, j), (ch + 1, i + oy, j + ox)).reshape(oy * ox))
    return jnp.stack(rows)


def conv2d_multi_im2col_ref(image: jax.Array, filters: jax.Array) -> jax.Array:
    """Eq. (1) through an explicit im2col + GEMM — a third oracle form."""
    m, c, k, _ = filters.shape
    oy, ox = image.shape[1] - k + 1, image.shape[2] - k + 1
    patches = im2col_matrix(image.astype(jnp.float32), k)
    flat = filters.reshape(m, c * k * k).astype(jnp.float32) @ patches
    return flat.reshape(m, oy, ox).astype(image.dtype)
