"""L2 baseline — FFT convolution (the paper's §1 category 2, [13]).

FFT convolution computes eq. (1) as a pointwise product in the frequency
domain:  O^m = sum_ch IFFT( FFT(I^ch) .* conj-flip(FFT(F^{ch,m})) ),
profitable only when K is large relative to the map (which is why cuDNN
rarely picks it for K in {1,3,5} — exactly the regime this paper
targets).  Implemented at the JAX level (an FFT Pallas kernel is out of
scope; XLA's FFT is already fused), verified against the direct oracle,
and mirrored by a timing plan in rust/src/baselines/fft_conv.rs.

Cross-correlation (the paper's operator) in the frequency domain uses
the complex conjugate of the filter transform.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["conv2d_fft"]


def conv2d_fft(image: jax.Array, filters: jax.Array) -> jax.Array:
    """Multi-channel valid cross-correlation (eq. 1) via 2-D FFT.

    image (C, Wy, Wx), filters (M, C, K, K) -> (M, Oy, Ox).
    Also accepts single-channel operands ((Wy,Wx) + (M,K,K)).
    """
    if image.ndim == 2:
        image = image[None]
        filters = filters[:, None]
    c, wy, wx = image.shape
    m, c2, k, _ = filters.shape
    assert c == c2, "channel mismatch"
    oy, ox = wy - k + 1, wx - k + 1

    fi = jnp.fft.rfft2(image.astype(jnp.float32), (wy, wx))          # (C, Wy, Wx//2+1)
    ff = jnp.fft.rfft2(filters.astype(jnp.float32), (wy, wx))        # (M, C, ...)
    # cross-correlation = product with the conjugate filter spectrum
    prod = jnp.einsum("cyx,mcyx->myx", fi, jnp.conj(ff))
    full = jnp.fft.irfft2(prod, (wy, wx))                            # (M, Wy, Wx)
    # valid region of the correlation starts at (0, 0)
    return full[:, :oy, :ox].astype(image.dtype)
