"""L1 Pallas kernel — Winograd F(2x2, 3x3) convolution baseline.

The paper's §1 taxonomy lists four GPU-convolution families: direct,
FFT-based, Winograd-based and GEMM-based.  The evaluation compares
against cuDNN (GEMM family); this kernel implements the Winograd family
[8] so the taxonomy is executable end-to-end (see
rust/src/baselines/winograd.rs for its timing plan).

F(2x2, 3x3): each 2x2 output tile is computed from a 4x4 input tile via

    Y = A^T [ (G g G^T) .* (B^T d B) ] A

with the standard transform matrices.  16 multiplies replace 36 — a
2.25x arithmetic reduction at the cost of transform overhead and 4x4
input tiles overlapping by 2.

Kernel structure mirrors the stride-fixed kernel: grid = (m-groups,
channel segments), segment axis innermost and accumulating; per step the
tile transforms are batched einsums (MXU-shaped) over all tiles.

Constraints: K = 3 only; odd output sizes are handled in the wrapper by
padding the image and cropping the result.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv2d_winograd"]

# transform matrices for F(2x2, 3x3)
_BT = jnp.array(
    [[1.0, 0.0, -1.0, 0.0], [0.0, 1.0, 1.0, 0.0], [0.0, -1.0, 1.0, 0.0], [0.0, 1.0, 0.0, -1.0]],
    jnp.float32,
)
_G = jnp.array(
    [[1.0, 0.0, 0.0], [0.5, 0.5, 0.5], [0.5, -0.5, 0.5], [0.0, 0.0, 1.0]], jnp.float32
)
_AT = jnp.array([[1.0, 1.0, 1.0, 0.0], [0.0, 1.0, -1.0, -1.0]], jnp.float32)


def _kernel(bt_ref, g_ref, at_ref, img_ref, flt_ref, out_ref, *, ty: int, tx: int):
    """One grid step: accumulate one channel segment, all tiles.

    bt/g/at : the F(2x2,3x3) transform matrices (pallas kernels cannot
              close over constants — they ride along as inputs)
    img_ref : (c_seg, Wy, Wx)   with Wy = 2*ty + 2, Wx = 2*tx + 2
    flt_ref : (m_blk, c_seg, 3, 3)
    out_ref : (m_blk, 2*ty, 2*tx)
    """
    s = pl.program_id(1)
    _BT = bt_ref[...]
    _G = g_ref[...]
    _AT = at_ref[...]

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    img = img_ref[...].astype(jnp.float32)
    flt = flt_ref[...].astype(jnp.float32)
    c_seg = img.shape[0]
    m_blk = flt.shape[0]

    # gather the overlapping 4x4 input tiles: (c_seg, ty, tx, 4, 4)
    tiles = jnp.stack(
        [
            jnp.stack(
                [
                    jax.lax.slice(
                        img,
                        (0, i, j),
                        (c_seg, i + 2 * (ty - 1) + 1, j + 2 * (tx - 1) + 1),
                        (1, 2, 2),
                    )
                    for j in range(4)
                ],
                axis=-1,
            )
            for i in range(4)
        ],
        axis=-2,
    )  # (c_seg, ty, tx, 4, 4)

    # input transform: V = B^T d B  per tile
    v = jnp.einsum("ab,ctxbd,de->ctxae", _BT, tiles, _BT.T)
    # filter transform: U = G g G^T  -> (m_blk, c_seg, 4, 4)
    u = jnp.einsum("ab,mcbd,de->mcae", _G, flt, _G.T)
    # elementwise product summed over channels: (m_blk, ty, tx, 4, 4)
    muv = jnp.einsum("mcae,ctxae->mtxae", u, v)
    # output transform: Y = A^T M A -> (m_blk, ty, tx, 2, 2)
    y = jnp.einsum("ab,mtxbd,de->mtxae", _AT, muv, _AT.T)
    # scatter the 2x2 tiles back to (m_blk, 2*ty, 2*tx)
    y = y.transpose(0, 1, 3, 2, 4).reshape(m_blk, 2 * ty, 2 * tx)
    out_ref[...] = out_ref[...] + y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m_blk", "c_seg"))
def _conv2d_winograd_tiled(image, filters, m_blk: int, c_seg: int):
    c, wy, wx = image.shape
    m = filters.shape[0]
    ty, tx = (wy - 2) // 2, (wx - 2) // 2
    grid = (m // m_blk, c // c_seg)
    return pl.pallas_call(
        functools.partial(_kernel, ty=ty, tx=tx),
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, 4), lambda mi, s: (0, 0)),
            pl.BlockSpec((4, 3), lambda mi, s: (0, 0)),
            pl.BlockSpec((2, 4), lambda mi, s: (0, 0)),
            pl.BlockSpec((c_seg, wy, wx), lambda mi, s: (s, 0, 0)),
            pl.BlockSpec((m_blk, c_seg, 3, 3), lambda mi, s: (mi, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m_blk, 2 * ty, 2 * tx), lambda mi, s: (mi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 2 * ty, 2 * tx), image.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(_BT, _G, _AT, image, filters)


def conv2d_winograd(image: jax.Array, filters: jax.Array,
                    m_blk: int | None = None, c_seg: int | None = None) -> jax.Array:
    """Multi-channel K=3 convolution (eq. 1) via Winograd F(2x2, 3x3).

    Accepts single-channel operands (image (Wy,Wx), filters (M,3,3)) by
    lifting to C=1.  Output sizes that are not even are produced by
    padding the input and cropping.
    """
    if image.ndim == 2:
        image = image[None]
        filters = filters[:, None]
    c, wy, wx = image.shape
    m, c2, k, k2 = filters.shape
    assert c == c2, "channel mismatch"
    if k != 3 or k2 != 3:
        raise ValueError("Winograd F(2x2,3x3) requires K=3")
    oy, ox = wy - 2, wx - 2
    # pad so the output is even in both dims
    pad_y, pad_x = oy % 2, ox % 2
    if pad_y or pad_x:
        image = jnp.pad(image, ((0, 0), (0, pad_y), (0, pad_x)))
        wy, wx = wy + pad_y, wx + pad_x
    if m_blk is None:
        m_blk = m if m <= 32 else next(d for d in range(32, 0, -1) if m % d == 0)
    if c_seg is None:
        c_seg = min(8, c)
        while c % c_seg:
            c_seg -= 1
    if m % m_blk or c % c_seg:
        raise ValueError(f"blocks must divide: M={m}%%{m_blk}, C={c}%%{c_seg}")
    out = _conv2d_winograd_tiled(image, filters, m_blk, c_seg)
    return out[:, :oy, :ox]
