"""L1 Pallas kernel — multi-channel convolution, stride-fixed block (§3.2).

The paper's *stride-fixed block* method fetches, per round and per SM:

  * an S-byte segment of each of M' filters along the ``ch`` dimension
    (S in {32, 64} bytes — the coalescing minimum, small so M' can be
    large), and
  * a W'x-pixel strip of the feature map of the matching channels,

then computes all M' filters against the strip while the next round's
segments prefetch.  The knobs: S fixes the channel-block depth
``c_seg = S / (K*K*4)`` (for K=1, S/4 channels per segment; for K>1 a
segment spans several taps of fewer channels — we round to whole
channels, the natural TPU re-tiling), W'x fixes the strip width, and
M' >= N_FMA*4/(S*W'x) fixes the output-filter parallelism.

TPU mapping: the segment stream becomes the *contraction-blocked* grid
dimension.  grid = (M/m_blk, C/c_seg) with the channel-segment axis
innermost; the output block index map ignores it, so the output block
stays resident in VMEM while segments stream through — exactly the
paper's "red pixels held for the next round" trick.  Each tap's update is

    out(m_blk, Oy*Ox) += F[m_blk, c_seg, i, j] @ I[c_seg, win(i,j)]

an (m_blk x c_seg) @ (c_seg x Oy*Ox) matmul: the inner loop the paper
feeds its FMA units is literally MXU-shaped here.  The Pallas grid
pipeline double-buffers the segment fetches, playing the role of the
paper's explicit prefetch; the <= S_shared/2 constraint of §3.2(4) is
the two-slot pipeline buffer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv2d_multi", "choose_multi_tiles"]


def _kernel(img_ref, flt_ref, out_ref, *, k: int, oy: int, ox: int):
    """One grid step: accumulate one channel segment into the out block.

    img_ref : (c_seg, Wy, Wx)        this segment's map channels
    flt_ref : (m_blk, c_seg, k, k)   this segment's filter block
    out_ref : (m_blk, oy, ox)        revisited across the segment axis
    """
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    img = img_ref[...]
    flt = flt_ref[...]
    m_blk, c_seg = flt.shape[0], flt.shape[1]
    acc = jnp.zeros((m_blk, oy * ox), dtype=jnp.float32)
    # K*K unrolled taps; each is an MXU-shaped (m_blk, c_seg)@(c_seg, oy*ox).
    for i in range(k):
        for j in range(k):
            win = jax.lax.slice(img, (0, i, j), (c_seg, i + oy, j + ox))
            acc = acc + jax.lax.dot(
                flt[:, :, i, j].astype(jnp.float32),
                win.reshape(c_seg, oy * ox).astype(jnp.float32),
                precision=jax.lax.Precision.HIGHEST,
            )
    out_ref[...] = out_ref[...] + acc.reshape(m_blk, oy, ox).astype(out_ref.dtype)


def choose_multi_tiles(c: int, wy: int, wx: int, m: int, k: int,
                       *, segment_bytes: int = 32,
                       max_block_floats: int = 24 * 1024) -> tuple[int, int]:
    """Pick (m_blk, c_seg) — the Pallas analogue of the §3.2 (S, M') step.

    ``segment_bytes`` is the paper's S: the filter bytes fetched per
    filter per round. c_seg = max(1, S / (K*K*4)) channels, rounded to a
    divisor of C. m_blk is then the largest divisor of M whose block
    working set fits ``max_block_floats`` (the S_shared/2 double-buffer
    constraint at f32).
    """
    tap_bytes = k * k * 4
    want = max(1, segment_bytes // tap_bytes)
    c_seg = 1
    for d in range(1, c + 1):
        if c % d == 0 and d <= want:
            c_seg = d
    oy, ox = wy - k + 1, wx - k + 1
    m_blk = 1
    for d in range(1, m + 1):
        if m % d == 0:
            work = d * c_seg * k * k + c_seg * wy * wx + d * oy * ox
            if work <= max_block_floats:
                m_blk = d
    return m_blk, c_seg


@functools.partial(jax.jit, static_argnames=("m_blk", "c_seg"))
def _conv2d_multi_tiled(image, filters, m_blk: int, c_seg: int):
    c, wy, wx = image.shape
    m, _, k, _ = filters.shape
    oy, ox = wy - k + 1, wx - k + 1
    # channel-segment axis innermost: segments stream while the output
    # block stays resident (the paper's round structure, Fig. 3).
    grid = (m // m_blk, c // c_seg)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, oy=oy, ox=ox),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c_seg, wy, wx), lambda mi, s: (s, 0, 0)),
            pl.BlockSpec((m_blk, c_seg, k, k), lambda mi, s: (mi, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m_blk, oy, ox), lambda mi, s: (mi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, oy, ox), image.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(image, filters)


def conv2d_multi(image: jax.Array, filters: jax.Array,
                 m_blk: int | None = None, c_seg: int | None = None,
                 segment_bytes: int = 32) -> jax.Array:
    """Multi-channel convolution (eq. 1) via the stride-fixed block kernel.

    ``m_blk``/``c_seg`` default to :func:`choose_multi_tiles` with the
    paper's S = ``segment_bytes``; pass them explicitly to reproduce a
    specific (S, M') point of the §3.2 ablation.
    """
    c, wy, wx = image.shape
    m, c2, k, _ = filters.shape
    assert c == c2, "channel mismatch"
    if m_blk is None or c_seg is None:
        auto_m, auto_c = choose_multi_tiles(c, wy, wx, m, k, segment_bytes=segment_bytes)
        m_blk = m_blk or auto_m
        c_seg = c_seg or auto_c
    if m % m_blk or c % c_seg:
        raise ValueError(f"blocks must divide: M={m} %% m_blk={m_blk}, C={c} %% c_seg={c_seg}")
    return _conv2d_multi_tiled(image, filters, m_blk, c_seg)
