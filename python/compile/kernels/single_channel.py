"""L1 Pallas kernel — single-channel convolution (paper §3.1).

The paper divides the work across SMs in one of two ways and picks the
division with the closed-form P/Q procedure:

  * method 1: filters divided along ``m`` (each SM owns ceil(M/N_sm)
    filters), the feature map cut into ``P`` pieces along ``y`` and
    streamed through on-chip memory with prefetching;
  * method 2: the feature map divided along ``y`` (each SM owns a strip),
    the filters cut into ``Q`` pieces and streamed.

On the TPU model both divisions become the *grid* of one Pallas kernel:

  grid = (M / m_tile,  Oy / y_tile)

A grid step owns an ``m_tile x y_tile`` output block — exactly the
(filters-per-SM x map-piece) working set of the paper — and the Pallas
grid pipeline plays the role of the paper's double-buffered prefetch:
while step g computes, the BlockSpec machinery fetches step g+1's blocks
HBM->VMEM.  Method 1 corresponds to iterating y-tiles innermost (the map
streams past resident filters), method 2 to iterating m-tiles innermost;
``P``/``Q`` are the respective grid extents.

The y-halo (each map piece needs K-1 extra rows, eq. (5)) cannot be
expressed as a non-overlapping BlockSpec, so the image is passed
unblocked and the kernel slices its ``y_tile + K - 1`` rows with a
dynamic slice — the VMEM working set still matches eq. (5):

  D1 = m_tile*K*K + (y_tile + K - 1) * Wx   floats.

The kernel body unrolls the K*K taps; each tap is a rank-3 broadcast
multiply-accumulate (VPU-shaped, (m_tile, y_tile, Ox) lanes).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv2d_single", "choose_single_tiles"]


def _kernel(img_ref, flt_ref, out_ref, *, k: int, y_tile: int, ox: int):
    """One grid step: out[m_tile, y_tile, ox] for this (m, p) block.

    img_ref : (Wy, Wx)            full image, resident (paper: the map
                                  piece + K-1 halo rows in shared memory)
    flt_ref : (m_tile, k, k)      this step's filter block
    out_ref : (m_tile, y_tile, ox)
    """
    p = pl.program_id(1)
    y0 = p * y_tile
    # The paper's eq.(5) working set: y_tile + K - 1 rows starting at y0.
    rows = img_ref[pl.ds(y0, y_tile + k - 1), :]
    flt = flt_ref[...]
    acc = jnp.zeros(out_ref.shape, dtype=jnp.float32)
    # Unrolled K*K taps (K <= 5 in every CNN suite the paper tests).
    for i in range(k):
        for j in range(k):
            win = jax.lax.slice(rows, (i, j), (i + y_tile, j + ox))
            acc = acc + win[None].astype(jnp.float32) * flt[:, i, j][:, None, None].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def choose_single_tiles(wy: int, wx: int, m: int, k: int,
                        *, max_block_floats: int = 24 * 1024) -> tuple[int, int]:
    """Pick (m_tile, y_tile) — the Pallas analogue of the paper's P/Q step.

    The authoritative P/Q procedure (with N_FMA / S_shared / register
    bounds) lives in ``rust/src/analytic``; this helper only needs a
    *feasible* tiling for the AOT'd kernels: block working set under
    ``max_block_floats`` (a 96 KB shared-memory stand-in at f32), tiles
    exact divisors so the grid covers the output with no remainder.
    """
    oy, ox = wy - k + 1, wx - k + 1
    assert oy >= 1 and ox >= 1, "filter larger than image"

    def divisors(n):
        return sorted((d for d in range(1, n + 1) if n % d == 0), reverse=True)

    def working_set(mt, yt):
        # eq.(5): output block + filter block + map piece with K-1 halo rows
        return mt * yt * ox + mt * k * k + (yt + k - 1) * wx

    # Joint search, largest m_tile first (more output reuse per fetched
    # map row — the paper's "higher FMA per loaded data" objective).
    for mt in divisors(m):
        for yt in divisors(oy):
            if working_set(mt, yt) <= max_block_floats:
                return mt, yt
    return 1, 1  # degenerate fallback (correct, just small blocks)


@functools.partial(jax.jit, static_argnames=("m_tile", "y_tile"))
def _conv2d_single_tiled(image, filters, m_tile: int, y_tile: int):
    wy, wx = image.shape
    m, k, _ = filters.shape
    oy, ox = wy - k + 1, wx - k + 1
    grid = (m // m_tile, oy // y_tile)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, y_tile=y_tile, ox=ox),
        grid=grid,
        in_specs=[
            # image: unblocked (halo handled by in-kernel dynamic slice)
            pl.BlockSpec((wy, wx), lambda mi, p: (0, 0)),
            # filters: blocked along m only — method-1's per-SM filter set
            pl.BlockSpec((m_tile, k, k), lambda mi, p: (mi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m_tile, y_tile, ox), lambda mi, p: (mi, p, 0)),
        out_shape=jax.ShapeDtypeStruct((m, oy, ox), image.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(image, filters)


def conv2d_single(image: jax.Array, filters: jax.Array,
                  m_tile: int | None = None, y_tile: int | None = None) -> jax.Array:
    """Single-channel convolution (eq. 2) via the §3.1 tiled Pallas kernel.

    ``m_tile``/``y_tile`` default to :func:`choose_single_tiles`; pass
    them explicitly to reproduce a specific P/Q division (P = Oy/y_tile,
    Q = M/m_tile).
    """
    wy, wx = image.shape
    m, k, _ = filters.shape
    if m_tile is None or y_tile is None:
        auto_m, auto_y = choose_single_tiles(wy, wx, m, k)
        m_tile = m_tile or auto_m
        y_tile = y_tile or auto_y
    oy = wy - k + 1
    if m % m_tile or oy % y_tile:
        raise ValueError(f"tiles must divide: M={m} %% m_tile={m_tile}, Oy={oy} %% y_tile={y_tile}")
    return _conv2d_single_tiled(image, filters, m_tile, y_tile)
