"""AOT lowering: JAX (L2+L1) -> HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 (behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`).  The text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is lowered with ``return_tuple=True``; the rust side
unwraps with ``to_tuple1()``.

Alongside the ``*.hlo.txt`` files a ``manifest.txt`` is written, one
artifact per line, ``key=value`` fields separated by whitespace:

  name=multi_c32_w14_m32_k3 kind=conv_multi file=multi_c32_w14_m32_k3.hlo.txt \
      c=32 wy=14 wx=14 m=32 k=3 dtype=f32

The rust runtime (`rust/src/runtime/manifest.rs`) parses exactly this.
Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Catalog: every artifact the rust side knows about.  Conv shapes cover the
# regimes of Figs. 4/5 at CPU-tractable sizes (the timing sweeps run in the
# gpusim substrate; these artifacts carry the *numerics*).
# ---------------------------------------------------------------------------


def catalog():
    """Yield (name, fn, meta) for every artifact."""
    singles = [
        # (wy, wx, m, k) — small-map regime of Fig. 4
        (28, 28, 64, 1),
        (32, 32, 32, 3),
        (64, 64, 16, 5),
        (56, 56, 32, 3),
    ]
    for wy, wx, m, k in singles:
        name = f"single_w{wy}_m{m}_k{k}"
        fn = model.make_conv_single(wy, wx, m, k)
        yield name, fn, dict(kind="conv_single", wy=wy, wx=wx, m=m, k=k, dtype="f32")

    multis = [
        # (c, wy, wx, m, k) — Fig. 5 regimes incl. the 7x7/K=3 deep-layer case
        (16, 28, 28, 16, 1),
        (32, 14, 14, 32, 3),
        (64, 7, 7, 64, 3),
        (16, 16, 16, 16, 5),
    ]
    for c, wy, wx, m, k in multis:
        name = f"multi_c{c}_w{wy}_m{m}_k{k}"
        fn = model.make_conv_multi(c, wy, wx, m, k)
        yield name, fn, dict(kind="conv_multi", c=c, wy=wy, wx=wx, m=m, k=k, dtype="f32")

    # Implicit-GEMM baseline numerics for one representative shape: the
    # rust integration tests check it agrees with the stride-fixed kernel.
    c, wy, wx, m, k = 32, 14, 14, 32, 3
    yield (f"im2col_c{c}_w{wy}_m{m}_k{k}",
           model.make_conv_im2col(c, wy, wx, m, k),
           dict(kind="conv_im2col", c=c, wy=wy, wx=wx, m=m, k=k, dtype="f32"))

    # Algorithm-taxonomy baselines (§1 categories 2 and 3) for one
    # representative shape each — the rust integration tests check all
    # four families agree numerically through PJRT.
    c, wy, wx, m = 32, 14, 14, 32
    yield (f"winograd_c{c}_w{wy}_m{m}_k3",
           model.make_conv_winograd(c, wy, wx, m),
           dict(kind="conv_winograd", c=c, wy=wy, wx=wx, m=m, k=3, dtype="f32"))
    yield (f"fft_c{c}_w{wy}_m{m}_k3",
           model.make_conv_fft(c, wy, wx, m, 3),
           dict(kind="conv_fft", c=c, wy=wy, wx=wx, m=m, k=3, dtype="f32"))

    # End-to-end serving workload.
    for batch in (1, 8):
        yield (f"papernet_b{batch}",
               model.make_papernet(batch),
               dict(kind="cnn", batch=batch, classes=10, in_c=1, in_h=28, in_w=28,
                    dtype="f32"))


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    ``print_large_constants=True`` is essential: the default HLO printer
    elides big literals as ``constant({...})``, which the rust-side text
    parser silently reads back as zeros — PaperNet's baked weights would
    vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_one(fn) -> str:
    lowered = jax.jit(fn).lower(*fn.arg_specs)
    return to_hlo_text(lowered)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="build a single artifact by name")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = []
    t0 = time.time()
    for name, fn, meta in catalog():
        if args.only and name != args.only:
            continue
        path = os.path.join(args.out, f"{name}.hlo.txt")
        t = time.time()
        text = lower_one(fn)
        with open(path, "w") as f:
            f.write(text)
        fields = " ".join(f"{k}={v}" for k, v in meta.items())
        manifest_lines.append(f"name={name} file={name}.hlo.txt {fields}")
        print(f"  {name}: {len(text) / 1e3:.0f} kB in {time.time() - t:.1f}s", flush=True)

    if not args.only:
        with open(os.path.join(args.out, "manifest.txt"), "w") as f:
            f.write("# pasconv artifact manifest — parsed by rust/src/runtime/manifest.rs\n")
            f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines)} artifacts to {args.out} "
          f"in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
