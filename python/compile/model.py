"""L2 — JAX compute graphs built on the L1 Pallas kernels.

Two kinds of graphs get AOT-lowered for the rust runtime:

  * **conv services** — a single convolution (single-channel §3.1,
    multi-channel §3.2, or the Implicit-GEMM baseline) with image and
    filters as runtime parameters.  These are the units the L3
    coordinator routes requests to.
  * **PaperNet** — a small LeNet-flavoured CNN whose conv layers are the
    paper's tested shapes (single-channel first layer, multi-channel
    rest, K in {1,3,5}), with weights baked at build time from a fixed
    seed.  This is the end-to-end serving workload; only the image batch
    is a runtime parameter.

Everything here runs at *build* time only; `aot.py` lowers these
functions to HLO text and the rust binary is self-contained afterwards.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv2d_fft, conv2d_im2col, conv2d_multi, conv2d_single, conv2d_winograd

__all__ = [
    "make_conv_single",
    "make_conv_multi",
    "make_conv_im2col",
    "make_conv_winograd",
    "make_conv_fft",
    "papernet_params",
    "papernet_apply",
    "make_papernet",
    "PAPERNET_LAYERS",
]


def make_conv_single(wy: int, wx: int, m: int, k: int,
                     m_tile: int | None = None, y_tile: int | None = None) -> Callable:
    """Conv service: (image (Wy,Wx), filters (M,K,K)) -> (out,)."""

    def fn(image, filters):
        return (conv2d_single(image, filters, m_tile=m_tile, y_tile=y_tile),)

    fn.arg_specs = (
        jax.ShapeDtypeStruct((wy, wx), jnp.float32),
        jax.ShapeDtypeStruct((m, k, k), jnp.float32),
    )
    return fn


def make_conv_multi(c: int, wy: int, wx: int, m: int, k: int,
                    m_blk: int | None = None, c_seg: int | None = None,
                    segment_bytes: int = 32) -> Callable:
    """Conv service: (image (C,Wy,Wx), filters (M,C,K,K)) -> (out,)."""

    def fn(image, filters):
        return (conv2d_multi(image, filters, m_blk=m_blk, c_seg=c_seg,
                             segment_bytes=segment_bytes),)

    fn.arg_specs = (
        jax.ShapeDtypeStruct((c, wy, wx), jnp.float32),
        jax.ShapeDtypeStruct((m, c, k, k), jnp.float32),
    )
    return fn


def make_conv_im2col(c: int, wy: int, wx: int, m: int, k: int) -> Callable:
    """Baseline conv service with Implicit-GEMM numerics."""

    def fn(image, filters):
        return (conv2d_im2col(image, filters),)

    fn.arg_specs = (
        jax.ShapeDtypeStruct((c, wy, wx), jnp.float32),
        jax.ShapeDtypeStruct((m, c, k, k), jnp.float32),
    )
    return fn


def make_conv_winograd(c: int, wy: int, wx: int, m: int) -> Callable:
    """Baseline conv service with Winograd F(2x2,3x3) numerics (K=3)."""

    def fn(image, filters):
        return (conv2d_winograd(image, filters),)

    fn.arg_specs = (
        jax.ShapeDtypeStruct((c, wy, wx), jnp.float32),
        jax.ShapeDtypeStruct((m, c, 3, 3), jnp.float32),
    )
    return fn


def make_conv_fft(c: int, wy: int, wx: int, m: int, k: int) -> Callable:
    """Baseline conv service with FFT numerics (§1 category 2)."""

    def fn(image, filters):
        return (conv2d_fft(image, filters),)

    fn.arg_specs = (
        jax.ShapeDtypeStruct((c, wy, wx), jnp.float32),
        jax.ShapeDtypeStruct((m, c, k, k), jnp.float32),
    )
    return fn


# --------------------------------------------------------------------------
# PaperNet — the end-to-end serving workload.
#
# Layer shapes deliberately mirror the paper's evaluation: a single-channel
# K=5 stem (the "first layer" case of §3.1), multi-channel K=3 body layers
# and a K=1 (pointwise) layer, on small maps (28 -> 24 -> 12 -> 10 -> 5),
# i.e. exactly the "feature map smaller than 32" regime the paper says
# prior work [1] handles poorly.
# --------------------------------------------------------------------------

PAPERNET_LAYERS = (
    # (kind, C, M, K) at the map size it sees
    ("single", 1, 8, 5),    # 28x28 -> 24x24, pool -> 12x12
    ("multi", 8, 16, 3),    # 12x12 -> 10x10, pool -> 5x5
    ("multi", 16, 32, 1),   # 5x5   -> 5x5   (pointwise)
    ("multi", 32, 32, 3),   # 5x5   -> 3x3
)
_NUM_CLASSES = 10


def papernet_params(seed: int = 0) -> dict:
    """Deterministic He-initialized weights, baked into the AOT artifact."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for idx, (kind, c, m, k) in enumerate(PAPERNET_LAYERS):
        key, sub = jax.random.split(key)
        fan_in = c * k * k
        w = jax.random.normal(sub, (m, c, k, k), jnp.float32) * jnp.sqrt(2.0 / fan_in)
        key, sub = jax.random.split(key)
        b = jnp.zeros((m,), jnp.float32)
        params[f"conv{idx}"] = (w, b)
    key, sub = jax.random.split(key)
    params["dense"] = (
        jax.random.normal(sub, (32 * 3 * 3, _NUM_CLASSES), jnp.float32) * 0.05,
        jnp.zeros((_NUM_CLASSES,), jnp.float32),
    )
    return params


def _pool2(x: jax.Array) -> jax.Array:
    """2x2 max pool over the trailing two dims of (M, H, W)."""
    m, h, w = x.shape
    x = x[:, : h - h % 2, : w - w % 2]
    x = x.reshape(m, h // 2, 2, w // 2, 2)
    return x.max(axis=(2, 4))


def papernet_apply(params: dict, image: jax.Array) -> jax.Array:
    """Forward pass for one (1, 28, 28) image -> (10,) logits.

    Every conv layer goes through the paper's kernels: the stem through
    the §3.1 single-channel kernel, the body through the §3.2
    stride-fixed block kernel.
    """
    x = image  # (1, 28, 28)
    for idx, (kind, c, m, k) in enumerate(PAPERNET_LAYERS):
        w, b = params[f"conv{idx}"]
        if kind == "single":
            y = conv2d_single(x[0], w[:, 0])
        else:
            y = conv2d_multi(x, w)
        y = jax.nn.relu(y + b[:, None, None])
        if idx < 2:  # pool after the first two layers (28->12->5)
            y = _pool2(y)
        x = y
    wd, bd = params["dense"]
    return x.reshape(-1) @ wd + bd


def make_papernet(batch: int, seed: int = 0) -> Callable:
    """AOT entry: (images (batch,1,28,28)) -> (logits (batch,10),).

    Weights are closed over (baked as HLO constants); the rust serve
    path feeds only image batches.
    """
    params = papernet_params(seed)

    def fn(images):
        return (jax.vmap(lambda im: papernet_apply(params, im))(images),)

    fn.arg_specs = (jax.ShapeDtypeStruct((batch, 1, 28, 28), jnp.float32),)
    return fn
